#!/usr/bin/env python3
"""Compare two BENCH_*.json harness artifacts across commits.

Usage:
    python3 tools/bench_compare.py OLD.json NEW.json

Both files are `flasheigen figures --bench-json` documents
({experiment, config, tables:[{title, headers, rows}]}).  Tables are
matched by title and rows by their first (key) column; for every
numeric cell the script prints old -> new with a new/old ratio, so a
CI run (or a human with two downloaded artifacts) can see at a glance
which timed columns moved between commits.

Cells carry units ("1.23s", "4.00MiB", "2.00KiB/s", "87%", "0.62x",
"12.5min") — values are normalised to a base unit before the ratio, so
"900.00KiB" -> "1.10MiB" compares as ~1.25x, not as 0.0012x.  Cells
whose units disagree after normalisation (or that are not numeric at
all) are printed verbatim without a ratio.

Residual columns (header contains "residual", e.g. the fig9_precision
"worst residual" column) get regression flagging on top of the ratio:
a residual is an accuracy floor, not a throughput, so the script flags
any cell that grew beyond RESIDUAL_RATIO x its old value while sitting
above the RESIDUAL_FLOOR noise level.  Comparison happens on the
normalised values, so the flag is unit-aware like every other ratio.

Exit status: 0 = compared fine, 2 = bad usage/unreadable input,
3 = the two documents share no table titles (nothing to compare),
4 = at least one residual column regressed.

Stdlib only — runs on the bare CI python3.
"""

import json
import re
import sys

# Multipliers to a base unit, keyed by the unit suffix of a cell.
# Binary byte units come from util::humansize; time units from
# util::timer::fmt_secs.  "/s" suffixes reuse the byte scales.
UNIT_SCALE = {
    "": ("", 1.0),
    "b": ("bytes", 1.0),
    "kib": ("bytes", 1024.0),
    "mib": ("bytes", 1024.0**2),
    "gib": ("bytes", 1024.0**3),
    "tib": ("bytes", 1024.0**4),
    "pib": ("bytes", 1024.0**5),
    "eib": ("bytes", 1024.0**6),
    "ns": ("secs", 1e-9),
    "us": ("secs", 1e-6),
    "ms": ("secs", 1e-3),
    "s": ("secs", 1.0),
    "min": ("secs", 60.0),
    "h": ("secs", 3600.0),
    "%": ("pct", 1.0),
    "x": ("ratio", 1.0),
}

CELL_RE = re.compile(r"^\s*([-+]?\d+(?:\.\d+)?(?:[eE][-+]?\d+)?)\s*([a-zA-Z%/]*)\s*$")

# A residual that grows past this multiple of its old value is a
# regression; anything at or below the floor is solver noise, not signal.
RESIDUAL_RATIO = 4.0
RESIDUAL_FLOOR = 1e-12


def parse_cell(cell):
    """-> (dimension, value-in-base-units) or None if non-numeric."""
    m = CELL_RE.match(cell)
    if not m:
        return None
    value, unit = float(m.group(1)), m.group(2)
    rate = unit.endswith("/s")
    if rate:
        unit = unit[:-2]
    scaled = UNIT_SCALE.get(unit.lower())
    if scaled is None:
        return None
    dim, mul = scaled
    return (dim + "/s" if rate else dim, value * mul)


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        raise SystemExit(2)
    tables = doc.get("tables")
    if not isinstance(tables, list):
        print(f"error: {path} has no 'tables' array (not a --bench-json artifact?)", file=sys.stderr)
        raise SystemExit(2)
    return doc


def compare_tables(old, new):
    """Print the per-cell comparison of two same-title tables.

    Returns the number of residual-column regressions found.
    """
    regressions = 0
    print(f"\n== {new['title']} ==")
    headers = new.get("headers", [])
    old_headers = old.get("headers", [])
    # Rows keyed by first column; first occurrence wins on duplicates.
    old_rows = {}
    for row in old.get("rows", []):
        if row:
            old_rows.setdefault(row[0], row)
    for row in new.get("rows", []):
        if not row:
            continue
        key = row[0]
        prev = old_rows.get(key)
        if prev is None:
            print(f"  {key}: (new row)")
            continue
        parts = []
        for i, cell in enumerate(row[1:], start=1):
            name = headers[i] if i < len(headers) else f"col{i}"
            # Align the old cell by header name, so a column set that
            # changed between commits (e.g. fig11 gaining qd/poll)
            # never pairs unrelated columns; positional matching is the
            # fallback only when the old artifact carries no headers.
            if name in old_headers:
                j = old_headers.index(name)
                before = prev[j] if j < len(prev) else None
            elif not old_headers:
                before = prev[i] if i < len(prev) else None
            else:
                before = None
            if before is None:
                parts.append(f"{name}: -> {cell} (new column)")
                continue
            a, b = parse_cell(before), parse_cell(cell)
            if a and b and a[0] == b[0] and a[1] != 0:
                line = f"{name}: {before} -> {cell} ({b[1] / a[1]:.2f}x)"
                if (
                    "residual" in name.lower()
                    and b[1] > a[1] * RESIDUAL_RATIO
                    and b[1] > RESIDUAL_FLOOR
                ):
                    line += "  !! residual regressed"
                    regressions += 1
                parts.append(line)
            elif before != cell:
                parts.append(f"{name}: {before} -> {cell}")
            else:
                parts.append(f"{name}: {cell}")
        print(f"  {key}:")
        for p in parts:
            print(f"    {p}")
    return regressions


def main(argv):
    if len(argv) != 3:
        print(__doc__.strip().splitlines()[2].strip(), file=sys.stderr)
        return 2
    old_doc, new_doc = load(argv[1]), load(argv[2])
    old_tables = {t["title"]: t for t in old_doc["tables"] if "title" in t}
    matched = 0
    regressions = 0
    for table in new_doc["tables"]:
        title = table.get("title")
        if title in old_tables:
            matched += 1
            regressions += compare_tables(old_tables[title], table)
    unmatched_new = [t["title"] for t in new_doc["tables"] if t.get("title") not in old_tables]
    unmatched_old = [t for t in old_tables if t not in {x.get("title") for x in new_doc["tables"]}]
    for t in unmatched_new:
        print(f"\n(table only in {argv[2]}: {t})")
    for t in unmatched_old:
        print(f"\n(table only in {argv[1]}: {t})")
    if matched == 0:
        print("error: the two artifacts share no table titles", file=sys.stderr)
        return 3
    print(f"\ncompared {matched} table(s)")
    if regressions:
        print(
            f"error: {regressions} residual cell(s) regressed beyond "
            f"{RESIDUAL_RATIO:.0f}x (floor {RESIDUAL_FLOOR:g})",
            file=sys.stderr,
        )
        return 4
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
