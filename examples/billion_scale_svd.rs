//! END-TO-END DRIVER (§4.3.2 / Table 3, scaled): singular value
//! decomposition of a directed, domain-clustered web graph with the full
//! FlashEigen stack — graph generation → tiled SCSR+COO image on the
//! simulated SSD array → semi-external SpMM (AᵀA operator) →
//! external-memory Block Krylov–Schur with the subspace on SSDs →
//! convergence log, resource accounting, and paper-shape checks.
//!
//! The paper computes 8 singular values of a 3.4B-vertex / 129B-edge page
//! graph in 4.2 h / 120 GB RAM / 145 TB read / 4 TB write.  This driver
//! runs the same pipeline at `--scale` (default 1/16384 ≈ 208K vertices,
//! 7.9M edges) on the time-dilated simulated array; the scale-free
//! quantities to compare are convergence, the read:write ratio, and the
//! memory staying flat in problem size (see EXPERIMENTS.md).
//!
//! ```bash
//! cargo run --release --example billion_scale_svd [-- --scale 6e-5 --xla]
//! ```

use flasheigen::eigen::{build_gram_operator, svd, EigenConfig, Which};
use flasheigen::graph::Dataset;
use flasheigen::harness::BenchCfg;
use flasheigen::runtime::{find_artifacts_dir, XlaKernels};
use flasheigen::spmm::SpmmOpts;
use flasheigen::util::cli::Args;
use flasheigen::util::humansize::{fmt_bytes, fmt_throughput};
use flasheigen::util::timer::{fmt_secs, time_it};
use std::sync::Arc;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["scale", "nev", "threads", "dilation", "seed"]).unwrap();
    let mut cfg = BenchCfg::from_env();
    cfg.scale = args.get_f64("scale", 1.0 / 16384.0).unwrap();
    cfg.threads = args.get_usize("threads", cfg.threads).unwrap();
    cfg.dilation = args.get_f64("dilation", cfg.dilation).unwrap();
    let nev = args.get_usize("nev", 8).unwrap();
    let use_xla = args.flag("xla");

    println!("=== billion-scale SVD driver (page graph, scale {:.2e}) ===", cfg.scale);

    // 1. Generate the domain-clustered directed web graph.
    let (coo, t_gen) = time_it(|| cfg.gen(Dataset::Page));
    println!(
        "[1] generated page graph: |V|={} |E|={} in {}",
        coo.n_rows,
        coo.nnz(),
        fmt_secs(t_gen)
    );

    // 2. Build the A and Aᵀ tile images on the simulated SSD array.
    let fs = cfg.timed_safs();
    let (op, t_build) = time_it(|| {
        build_gram_operator(&coo, cfg.tile_dim, Some(&fs), SpmmOpts::default(), cfg.threads)
    });
    println!(
        "[2] tile images on SSDs: A={} Aᵀ={} ({} tile rows) in {}",
        fmt_bytes(op.a.storage_bytes()),
        fmt_bytes(op.at.storage_bytes()),
        op.a.num_tile_rows(),
        fmt_secs(t_build)
    );

    // 3. Dense context: subspace on SSDs, most recent matrix cached.
    let kernels: Arc<dyn flasheigen::dense::DenseKernels> = if use_xla {
        let dir = find_artifacts_dir().expect("run `make artifacts` for --xla");
        Arc::new(XlaKernels::load(&dir).expect("load artifacts"))
    } else {
        Arc::new(flasheigen::dense::NativeKernels)
    };
    let ctx = cfg.dense_ctx(fs.clone(), /* em */ true, kernels);
    println!("[3] dense ctx: EM subspace, kernels={}", ctx.kernels.name());

    // 4. Solve (paper §4.3.2: block 2, 2·ev blocks for the page graph).
    let ecfg = EigenConfig {
        nev,
        block_size: 2,
        num_blocks: 2 * nev,
        tol: 1e-6,
        max_restarts: 300,
        which: Which::LargestAlgebraic,
        seed: cfg.seed,
        compute_eigenvectors: false,
        refine_steps: 0,
    };
    let before = fs.stats();
    let (res, t_solve) = time_it(|| svd(&op, &ctx, &ecfg));
    let delta = fs.stats().delta_since(&before);

    println!("[4] convergence log (worst top-{nev} residual per restart):");
    for (i, r) in res.history.iter().enumerate() {
        if i % 5 == 0 || i + 1 == res.history.len() {
            println!("      restart {i:>3}: {r:.3e}");
        }
    }
    println!("    singular values: {:?}", res.singular_values);
    println!(
        "    converged={} restarts={} AᵀA applies={}",
        res.converged, res.restarts, res.operator_applies
    );

    // 5. Table-3-style resource report.
    println!("[5] resources (Table 3 shape):");
    println!("      runtime       {}", fmt_secs(t_solve));
    println!("      memory (peak) {}", fmt_bytes(ctx.mem.peak()));
    println!("      SSD read      {}", fmt_bytes(delta.bytes_read));
    println!("      SSD write     {}", fmt_bytes(delta.bytes_written));
    println!(
        "      read:write    {:.1} (paper: {:.1})",
        delta.bytes_read as f64 / delta.bytes_written.max(1) as f64,
        145.0 / 4.0
    );
    println!(
        "      avg I/O rate  {} (array max {})",
        fmt_throughput(delta.total_bytes(), t_solve),
        fmt_bytes(cfg.safs_config().aggregate_read_bps() as u64)
    );
    println!("      device skew   {:.2}", fs.stats().skew());
    println!("      spmm phases:\n{}", op.timers.report());

    assert!(res.converged, "driver must converge");
    assert!(
        delta.bytes_read > 4 * delta.bytes_written,
        "read-dominated I/O expected (paper ratio ≈ 36:1)"
    );
    println!("=== done: all layers composed (graph → SAFS → SpMM → KrylovSchur) ===");
}
