//! Figure 8: sparse-multiply performance of Trilinos-like and FE-SEM
//! relative to FE-IM (SpMV and SpMM b=4) per graph.
use flasheigen::harness::{fig8, BenchCfg};

fn main() {
    let mut cfg = BenchCfg::from_env();
    // SpMM cache behaviour needs graphs whose dense vectors exceed the
    // CPU caches; run these figures at 8x the default dataset scale.
    cfg.scale *= 8.0;
    fig8(&cfg).print();
}
