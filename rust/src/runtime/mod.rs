//! PJRT runtime bridge: manifest parsing, lazy compilation of the
//! AOT-lowered JAX/Pallas HLO artifacts, and the XLA-backed
//! [`crate::dense::DenseKernels`] implementation used on the hot path.

pub mod manifest;
pub mod xla;

pub use manifest::{ArtifactMeta, Manifest};
pub use xla::{find_artifacts_dir, XlaKernels};
