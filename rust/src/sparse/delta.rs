//! Delta overlays: mutating a built tile image without rebuilding it.
//!
//! A [`DeltaBatch`] of edge insertions/deletions is merged into a
//! [`SparseMatrix`] by [`SparseMatrix::apply_delta`], which keeps the
//! base image untouched and parks the mutated tile rows in a
//! [`DeltaOverlay`].  `A·X` then runs as **base sweep + delta sweep
//! fused per tile row**: every SpMM path (eager, streamed, batched)
//! keeps reading the base image's byte ranges — walk geometry, byte
//! accounting, image-cache residency and read-ahead are all unchanged —
//! and substitutes the overlay's patched bytes at compute time for the
//! tile rows the deltas touched (deletions subtract by being absent
//! from the patched row).
//!
//! # Merge contract (normative)
//!
//! - Deltas share the base tile geometry: `tile_dim`, the tile-row
//!   grid, `value_elem` and the `coo_hybrid` encoding flag are fixed by
//!   the base build and every patched row is re-encoded with exactly
//!   those parameters.  A patched tile row is therefore **byte-identical**
//!   to the same tile row of a from-scratch [`build_matrix_opts`] of the
//!   mutated edge list — the overlay-vs-rebuilt differential props in
//!   `tests/props.rs` pin this bitwise, per SpMM path.
//! - Within one batch, deletions apply before insertions.  Deleting an
//!   absent edge is a counted no-op ([`DeltaStats::missed_deletes`]);
//!   inserting over an existing edge replaces its value
//!   ([`DeltaStats::updated`]).
//! - Unweighted images (`value_elem == 0`) only accept inserts with
//!   value exactly `1.0`; weighted images narrow inserted values to the
//!   image's stored width at encode time, exactly as the builder does.
//! - The in-RAM matrix index stays truthful: per-row and total `nnz`
//!   track the effective matrix, and the tile-column index extension
//!   (`col_offsets`/`col_ids`) is rebuilt from the patched rows so
//!   demand schedules see the mutated tile structure.  Per-row byte
//!   `offset`/`len` keep describing the **base** image — they are what
//!   the SEM walks read.
//!
//! # Compaction contract (normative)
//!
//! [`SparseMatrix::compact`] folds the overlay into a fresh base image:
//! the effective matrix is re-staged as COO and rebuilt with the same
//! `tile_dim`/`coo_hybrid`/value-width parameters, onto the same
//! storage (in-memory, or re-creating the same SAFS file — which
//! retires the old image's bytes and invalidates its cache entries).
//! Compaction is **bitwise-invariant**: `A·X` before and after compact
//! produce identical bits, and the compacted image equals a
//! from-scratch build of the mutated graph byte for byte.
//! [`SparseMatrix::maybe_compact`] triggers it once the cumulative
//! delta volume exceeds a tunable fraction of the base nnz
//! (`SafsConfig::delta_compact_frac`, `--delta-compact`,
//! `FLASHEIGEN_DELTA_COMPACT`; `0` disables).
//!
//! [`build_matrix_opts`]: super::builder::build_matrix_opts

use super::builder::{build_matrix_opts, BuildTarget, CooMatrix};
use super::matrix::{assemble_tile_row, SparseMatrix, Storage, TileRowView};
use super::tile::encode_tile_opts;
use std::collections::BTreeMap;

/// One batch of edge mutations against a built tile image.  Deletions
/// apply before insertions (see the module-level merge contract).
#[derive(Clone, Debug, Default)]
pub struct DeltaBatch {
    /// `(row, col, value)` — value must be `1.0` for unweighted images.
    pub inserts: Vec<(u32, u32, f64)>,
    /// `(row, col)` — deleting an absent edge is a counted no-op.
    pub deletes: Vec<(u32, u32)>,
}

impl DeltaBatch {
    pub fn new() -> DeltaBatch {
        DeltaBatch::default()
    }

    pub fn insert(&mut self, r: u32, c: u32, v: f64) {
        self.inserts.push((r, c, v));
    }

    /// Insert into an unweighted image (value 1.0).
    pub fn insert_unweighted(&mut self, r: u32, c: u32) {
        self.inserts.push((r, c, 1.0));
    }

    pub fn delete(&mut self, r: u32, c: u32) {
        self.deletes.push((r, c));
    }

    pub fn len(&self) -> usize {
        self.inserts.len() + self.deletes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inserts.is_empty() && self.deletes.is_empty()
    }

    /// The transposed batch (for SVD sessions, which hold images of both
    /// `A` and `Aᵀ` and must mutate them in lockstep).
    pub fn transpose(&self) -> DeltaBatch {
        DeltaBatch {
            inserts: self.inserts.iter().map(|&(r, c, v)| (c, r, v)).collect(),
            deletes: self.deletes.iter().map(|&(r, c)| (c, r)).collect(),
        }
    }
}

/// What one [`SparseMatrix::apply_delta`] call did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeltaStats {
    /// Edges newly added.
    pub inserted: u64,
    /// Inserts that replaced an existing edge's value.
    pub updated: u64,
    /// Edges removed.
    pub deleted: u64,
    /// Deletes of absent edges (no-ops).
    pub missed_deletes: u64,
}

/// The mutated tile rows parked over a base image, plus the compaction
/// accounting.  See the module docs for the merge/compaction contract.
#[derive(Clone, Debug, Default)]
pub struct DeltaOverlay {
    /// Patched tile-row images, byte-identical to a from-scratch build's
    /// rows for the mutated graph.  Keyed by tile-row index.
    pub rows: BTreeMap<usize, Vec<u8>>,
    /// Cumulative mutation volume (inserted + updated + deleted) across
    /// all applied batches — the compaction trigger numerator.
    pub delta_nnz: u64,
    /// `nnz` of the base image when the overlay was created.
    pub base_nnz: u64,
    /// Batches merged so far.
    pub batches: u64,
}

impl SparseMatrix {
    /// Merge one [`DeltaBatch`] into the overlay (see the module-level
    /// merge contract).  The base image bytes are not touched; every
    /// tile row the batch mutates is re-encoded into
    /// [`DeltaOverlay::rows`] and the in-RAM matrix index (`nnz`,
    /// tile-column extension) is updated to the effective matrix.
    pub fn apply_delta(&mut self, batch: &DeltaBatch) -> DeltaStats {
        let td = self.tile_dim as u64;
        let weighted = self.value_elem != 0;
        for &(r, c, v) in &batch.inserts {
            assert!(
                (r as u64) < self.n_rows && (c as u64) < self.n_cols,
                "delta insert ({r},{c}) out of bounds for {}x{}",
                self.n_rows,
                self.n_cols
            );
            assert!(
                weighted || v == 1.0,
                "unweighted image: insert value must be 1.0, got {v}"
            );
        }
        for &(r, c) in &batch.deletes {
            assert!(
                (r as u64) < self.n_rows && (c as u64) < self.n_cols,
                "delta delete ({r},{c}) out of bounds for {}x{}",
                self.n_rows,
                self.n_cols
            );
        }

        // Group mutations by tile row; within a row, deletes before
        // inserts (batch semantics).
        type RowOps = (Vec<(u32, u32)>, Vec<(u32, u32, f64)>);
        let mut by_row: BTreeMap<usize, RowOps> = BTreeMap::new();
        for &(r, c) in &batch.deletes {
            by_row.entry((r as u64 / td) as usize).or_default().0.push((r, c));
        }
        for &(r, c, v) in &batch.inserts {
            by_row.entry((r as u64 / td) as usize).or_default().1.push((r, c, v));
        }
        let mut stats = DeltaStats::default();
        if by_row.is_empty() {
            return stats;
        }
        if self.overlay.is_none() {
            self.overlay = Some(DeltaOverlay {
                rows: BTreeMap::new(),
                delta_nnz: 0,
                base_nnz: self.nnz,
                batches: 0,
            });
        }

        let tile_dim = self.tile_dim;
        let coo_hybrid = self.coo_hybrid;
        let enc_elem = self.value_elem.max(4);
        let mut buf = Vec::new();
        for (tr, (dels, ins)) in by_row {
            // Decode the current effective row (a prior patch wins over
            // the base bytes) into builder key order: (tile_col, row,
            // col) — the exact order `build_matrix_opts` encodes in.
            self.read_tile_row(tr, &mut buf);
            let mut cells: BTreeMap<(u32, u16, u16), f64> = BTreeMap::new();
            for (tile_col, view) in TileRowView::new(&buf, self.value_elem) {
                view.for_each(|r, c, v| {
                    cells.insert((tile_col, r, c), v);
                });
            }
            for (r, c) in dels {
                let key =
                    ((c as u64 / td) as u32, (r as u64 % td) as u16, (c as u64 % td) as u16);
                match cells.remove(&key) {
                    Some(_) => stats.deleted += 1,
                    None => stats.missed_deletes += 1,
                }
            }
            for (r, c, v) in ins {
                let key =
                    ((c as u64 / td) as u32, (r as u64 % td) as u16, (c as u64 % td) as u16);
                match cells.insert(key, v) {
                    Some(_) => stats.updated += 1,
                    None => stats.inserted += 1,
                }
            }
            // Re-encode with the base build's exact encoder parameters:
            // patched bytes == the same row of a from-scratch build.
            let mut tiles: Vec<(u32, Vec<u8>)> = Vec::new();
            let mut local: Vec<(u16, u16)> = Vec::new();
            let mut local_vals: Vec<f64> = Vec::new();
            let mut cur: Option<u32> = None;
            for (&(tc, r, c), &v) in &cells {
                if cur != Some(tc) {
                    if let Some(prev) = cur {
                        tiles.push((
                            prev,
                            encode_tile_opts(
                                &local,
                                weighted.then_some(&local_vals[..]),
                                tile_dim,
                                coo_hybrid,
                                enc_elem,
                            ),
                        ));
                        local.clear();
                        local_vals.clear();
                    }
                    cur = Some(tc);
                }
                local.push((r, c));
                if weighted {
                    local_vals.push(v);
                }
            }
            if let Some(prev) = cur {
                tiles.push((
                    prev,
                    encode_tile_opts(
                        &local,
                        weighted.then_some(&local_vals[..]),
                        tile_dim,
                        coo_hybrid,
                        enc_elem,
                    ),
                ));
            }
            let new_bytes = assemble_tile_row(&tiles);
            let old_nnz = self.index[tr].nnz;
            self.index[tr].nnz = cells.len() as u64;
            self.nnz = self.nnz + cells.len() as u64 - old_nnz;
            self.overlay.as_mut().unwrap().rows.insert(tr, new_bytes);
        }
        let ov = self.overlay.as_mut().unwrap();
        ov.delta_nnz += stats.inserted + stats.updated + stats.deleted;
        ov.batches += 1;
        self.rebuild_col_index();
        stats
    }

    /// Rebuild the flat tile-column index extension from the overlay's
    /// patched rows (unpatched rows copy their old slices).
    fn rebuild_col_index(&mut self) {
        let Some(ov) = &self.overlay else { return };
        let old_offsets = std::mem::take(&mut self.col_offsets);
        let old_ids = std::mem::take(&mut self.col_ids);
        let mut offsets: Vec<usize> = Vec::with_capacity(old_offsets.len());
        let mut ids: Vec<u32> = Vec::with_capacity(old_ids.len());
        offsets.push(0);
        for tr in 0..self.index.len() {
            match ov.rows.get(&tr) {
                Some(bytes) => {
                    ids.extend(TileRowView::new(bytes, self.value_elem).map(|(c, _)| c))
                }
                None => ids.extend_from_slice(&old_ids[old_offsets[tr]..old_offsets[tr + 1]]),
            }
            offsets.push(ids.len());
        }
        self.col_offsets = offsets;
        self.col_ids = ids;
    }

    /// Fold the overlay into a fresh base image (see the module-level
    /// compaction contract).  No-op without an overlay.  For SEM
    /// matrices this re-creates the same SAFS file, retiring the old
    /// image's bytes and invalidating its cache entries.
    pub fn compact(&mut self) {
        if self.overlay.is_none() {
            return;
        }
        let triples = self.to_triples();
        let mut coo = CooMatrix::new(self.n_rows, self.n_cols);
        coo.entries = triples.iter().map(|&(r, c, _)| (r as u32, c as u32)).collect();
        if self.value_elem != 0 {
            coo.values = Some(triples.iter().map(|&(_, _, v)| v).collect());
            coo.wide_values = self.value_elem == 8;
        }
        let rebuilt = match &self.storage {
            Storage::Mem(_) => {
                build_matrix_opts(&coo, self.tile_dim, BuildTarget::Mem, self.coo_hybrid)
            }
            Storage::Safs { fs, file } => {
                let fs = fs.clone();
                let name = file.name.clone();
                build_matrix_opts(
                    &coo,
                    self.tile_dim,
                    BuildTarget::Safs(&fs, &name),
                    self.coo_hybrid,
                )
            }
        };
        *self = rebuilt;
    }

    /// [`compact`](SparseMatrix::compact) once the cumulative delta
    /// volume reaches `frac` of the base nnz (`frac <= 0` disables).
    /// Returns whether compaction ran.
    pub fn maybe_compact(&mut self, frac: f64) -> bool {
        if frac <= 0.0 {
            return false;
        }
        let Some(ov) = &self.overlay else { return false };
        if (ov.delta_nnz as f64) < frac * ov.base_nnz.max(1) as f64 {
            return false;
        }
        self.compact();
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::safs::{Safs, SafsConfig};
    use crate::sparse::builder::build_matrix;
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, n: u64, nnz: usize, weighted: bool) -> CooMatrix {
        let mut coo = CooMatrix::new(n, n);
        for _ in 0..nnz {
            let r = rng.gen_range(n) as u32;
            let c = rng.gen_range(n) as u32;
            if weighted {
                coo.push_weighted(r, c, (r % 13) as f32 + 0.5);
            } else {
                coo.push(r, c);
            }
        }
        coo.sort_dedup();
        coo
    }

    /// The mutated edge list: coo minus deletes plus inserts.
    fn mutate_coo(coo: &CooMatrix, batch: &DeltaBatch) -> CooMatrix {
        let mut map: BTreeMap<(u32, u32), f64> = BTreeMap::new();
        for (i, &(r, c)) in coo.entries.iter().enumerate() {
            let v = coo.values.as_ref().map_or(1.0, |vs| vs[i]);
            map.insert((r, c), v);
        }
        for &(r, c) in &batch.deletes {
            map.remove(&(r, c));
        }
        for &(r, c, v) in &batch.inserts {
            map.insert((r, c), v);
        }
        let mut out = CooMatrix::new(coo.n_rows, coo.n_cols);
        out.wide_values = coo.wide_values;
        for (&(r, c), &v) in &map {
            out.entries.push((r, c));
            if coo.values.is_some() {
                out.values.get_or_insert_with(Vec::new).push(v);
            }
        }
        out
    }

    fn churn_batch(rng: &mut Rng, coo: &CooMatrix, ins: usize, dels: usize) -> DeltaBatch {
        let n = coo.n_rows;
        let mut b = DeltaBatch::new();
        for _ in 0..ins {
            let r = rng.gen_range(n) as u32;
            let c = rng.gen_range(n) as u32;
            if coo.values.is_some() {
                b.insert(r, c, (c % 7) as f32 as f64 + 0.25);
            } else {
                b.insert_unweighted(r, c);
            }
        }
        for _ in 0..dels {
            // Delete a mix of present and absent edges.
            if rng.gen_range(2) == 0 && !coo.entries.is_empty() {
                let i = rng.gen_range(coo.entries.len() as u64) as usize;
                b.delete(coo.entries[i].0, coo.entries[i].1);
            } else {
                b.delete(rng.gen_range(n) as u32, rng.gen_range(n) as u32);
            }
        }
        b
    }

    #[test]
    fn patched_rows_match_rebuilt_rows_bytewise() {
        for weighted in [false, true] {
            let mut rng = Rng::new(41);
            let coo = random_coo(&mut rng, 200, 1200, weighted);
            let mut m = build_matrix(&coo, 32, BuildTarget::Mem);
            let batch = churn_batch(&mut rng, &coo, 80, 60);
            m.apply_delta(&batch);
            let rebuilt = build_matrix(&mutate_coo(&coo, &batch), 32, BuildTarget::Mem);
            assert_eq!(m.nnz, rebuilt.nnz, "effective nnz (weighted={weighted})");
            let (mut a, mut b) = (Vec::new(), Vec::new());
            for tr in 0..m.num_tile_rows() {
                m.read_tile_row(tr, &mut a);
                rebuilt.read_tile_row(tr, &mut b);
                assert_eq!(a, b, "tile row {tr} bytes (weighted={weighted})");
                assert_eq!(m.index[tr].nnz, rebuilt.index[tr].nnz, "row {tr} nnz");
                assert_eq!(m.tile_cols(tr), rebuilt.tile_cols(tr), "row {tr} col index");
            }
            assert_eq!(m.to_triples(), rebuilt.to_triples());
        }
    }

    #[test]
    fn delta_stats_count_each_outcome() {
        let mut coo = CooMatrix::new(64, 64);
        coo.push_weighted(1, 2, 1.5);
        coo.push_weighted(3, 4, 2.5);
        coo.sort_dedup();
        let mut m = build_matrix(&coo, 16, BuildTarget::Mem);
        let mut b = DeltaBatch::new();
        b.insert(5, 6, 3.0); // new
        b.insert(1, 2, 9.0); // update
        b.delete(3, 4); // present
        b.delete(7, 8); // absent
        let st = m.apply_delta(&b);
        assert_eq!(
            st,
            DeltaStats { inserted: 1, updated: 1, deleted: 1, missed_deletes: 1 }
        );
        assert_eq!(m.nnz, 2);
        assert_eq!(
            m.to_triples(),
            vec![(1, 2, 9.0), (5, 6, 3.0)]
        );
    }

    #[test]
    fn all_deleted_row_yields_valid_empty_row() {
        let mut coo = CooMatrix::new(40, 40);
        coo.push(0, 1);
        coo.push(0, 2);
        coo.sort_dedup();
        let mut m = build_matrix(&coo, 16, BuildTarget::Mem);
        let mut b = DeltaBatch::new();
        b.delete(0, 1);
        b.delete(0, 2);
        m.apply_delta(&b);
        assert_eq!(m.nnz, 0);
        assert_eq!(m.index[0].nnz, 0);
        assert!(m.tile_cols(0).is_empty());
        assert!(m.to_triples().is_empty());
        // The patched row is the 8-byte empty tile row — still walkable.
        let mut buf = Vec::new();
        m.read_tile_row(0, &mut buf);
        assert_eq!(TileRowView::new(&buf, 0).count(), 0);
    }

    #[test]
    fn compaction_is_bitwise_invariant_and_equals_rebuild() {
        let mut rng = Rng::new(43);
        let coo = random_coo(&mut rng, 150, 900, true);
        let mut m = build_matrix(&coo, 32, BuildTarget::Mem);
        let batch = churn_batch(&mut rng, &coo, 50, 50);
        m.apply_delta(&batch);
        let before = m.to_triples();
        m.compact();
        assert!(m.overlay.is_none(), "compaction clears the overlay");
        assert_eq!(m.to_triples(), before, "compaction is value-invariant");
        let rebuilt = build_matrix(&mutate_coo(&coo, &batch), 32, BuildTarget::Mem);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for tr in 0..m.num_tile_rows() {
            m.read_tile_row(tr, &mut a);
            rebuilt.read_tile_row(tr, &mut b);
            assert_eq!(a, b, "compacted row {tr} == from-scratch row");
        }
        assert_eq!(m.storage_bytes(), rebuilt.storage_bytes());
    }

    #[test]
    fn compaction_recreates_the_safs_file_exactly() {
        let fs = Safs::new(SafsConfig::untimed());
        let mut rng = Rng::new(44);
        let coo = random_coo(&mut rng, 120, 800, false);
        let mut m = build_matrix(&coo, 32, BuildTarget::Safs(&fs, "img"));
        let batch = churn_batch(&mut rng, &coo, 40, 40);
        m.apply_delta(&batch);
        let written_before_compact = fs.stats().bytes_written;
        m.compact();
        assert!(m.is_external(), "compaction preserves the storage kind");
        // Attribution stays exact across the truncation: the old
        // image's counters fold into the retired map, so the per-name
        // sum still reproduces the array ledger.
        let s = fs.stats();
        assert_eq!(fs.file_bytes("img"), (s.bytes_read, s.bytes_written));
        assert!(
            s.bytes_written >= written_before_compact + m.storage_bytes(),
            "the compacted image was written to the array"
        );
        let rebuilt = build_matrix(&mutate_coo(&coo, &batch), 32, BuildTarget::Mem);
        assert_eq!(m.to_triples(), rebuilt.to_triples());
    }

    #[test]
    fn maybe_compact_honors_threshold_and_disable() {
        let mut rng = Rng::new(45);
        let coo = random_coo(&mut rng, 100, 500, false);
        let mut m = build_matrix(&coo, 32, BuildTarget::Mem);
        let batch = churn_batch(&mut rng, &coo, 30, 0);
        m.apply_delta(&batch);
        let applied = m.overlay.as_ref().unwrap().delta_nnz;
        assert!(applied > 0);
        assert!(!m.maybe_compact(0.0), "0 disables compaction");
        assert!(!m.maybe_compact(1.0), "below threshold");
        assert!(m.overlay.is_some());
        let frac = applied as f64 / m.overlay.as_ref().unwrap().base_nnz as f64;
        assert!(m.maybe_compact(frac * 0.5), "above threshold compacts");
        assert!(m.overlay.is_none());
    }

    #[test]
    #[should_panic(expected = "insert value must be 1.0")]
    fn unweighted_rejects_weighted_insert() {
        let mut coo = CooMatrix::new(10, 10);
        coo.push(0, 0);
        let mut m = build_matrix(&coo, 16, BuildTarget::Mem);
        let mut b = DeltaBatch::new();
        b.insert(1, 1, 2.0);
        m.apply_delta(&b);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn out_of_bounds_insert_is_rejected() {
        let coo = CooMatrix::new(10, 10);
        let mut m = build_matrix(&coo, 16, BuildTarget::Mem);
        let mut b = DeltaBatch::new();
        b.insert_unweighted(10, 0);
        m.apply_delta(&b);
    }
}
