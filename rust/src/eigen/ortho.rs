//! Block (re)orthogonalization (the step the paper attributes most of the
//! eigensolver's dense-matrix traffic to).
//!
//! Classical Gram–Schmidt done twice (CGS2, "twice is enough") against
//! the whole existing basis, expressed entirely in the Table-1 operations
//! `MvTransMv` (op3) and `MvTimesMatAddMv` (op1) — so in EM mode every
//! sweep streams the full subspace from the SSD array, which is exactly
//! why reorthogonalization dominates the paper's runtime at large nev.

use crate::dense::{mv_times_mat_add_mv, mv_trans_mv, tas::mv_random, SmallMat, TasMatrix};

/// Project `x` against the orthonormal basis blocks (`x -= V·(Vᵀx)`),
/// twice.  Returns the accumulated coefficients `C = Vᵀx` (m×b) from the
/// first pass plus the correction of the second (needed to extend the
/// projected matrix T).
pub fn ortho_against(basis: &[&TasMatrix], x: &TasMatrix) -> SmallMat {
    if basis.is_empty() {
        return SmallMat::zeros(0, x.n_cols);
    }
    // Pass 1.
    let c1 = mv_trans_mv(1.0, basis, x);
    mv_times_mat_add_mv(-1.0, basis, &c1, 1.0, x);
    // Pass 2 (correction for the rounding of pass 1).
    let c2 = mv_trans_mv(1.0, basis, x);
    mv_times_mat_add_mv(-1.0, basis, &c2, 1.0, x);
    // Total coefficients.
    let mut c = c1;
    for (a, b) in c.data.iter_mut().zip(&c2.data) {
        *a += b;
    }
    c
}

/// Orthonormalize the columns of `x` in place via Cholesky QR
/// (`G = XᵀX = RᵀR`, `X := X·R⁻¹`), retried once for stability.
/// Returns `R` (b×b upper triangular) such that `X_old = X_new · R`.
///
/// On rank deficiency (Cholesky breakdown) the offending block is
/// refreshed with random vectors, re-projected against `basis`, and the
/// corresponding rows of R are zero — the standard restart treatment.
pub fn normalize_block(x: &TasMatrix, basis: &[&TasMatrix], seed: u64) -> (SmallMat, bool) {
    let b = x.n_cols;
    let mut r_total = SmallMat::identity(b);
    let mut replaced = false;
    for attempt in 0..3 {
        let g = mv_trans_mv(1.0, &[x], x);
        // Breakdown tolerance relative to the largest diagonal.
        let dmax = (0..b).map(|i| g.at(i, i)).fold(0.0f64, f64::max);
        match g.cholesky_upper(1e-14 * dmax.max(1e-300)) {
            Some(r) => {
                // X := X · R⁻¹  (op1 with the inverse; in-place via alias).
                let rinv = SmallMat::inv_upper(&r);
                mv_times_mat_add_mv(1.0, &[x], &rinv, 0.0, x);
                // R_total := R · R_total.
                r_total = SmallMat::matmul(&r, &r_total);
                if attempt == 0 {
                    // One refinement pass tightens orthonormality.
                    continue;
                }
                return (r_total, replaced);
            }
            None => {
                // Rank deficient: replace with fresh random vectors,
                // project against everything, and try again.
                replaced = true;
                mv_random(x, seed.wrapping_add(attempt as u64 + 1));
                ortho_against(basis, x);
                r_total = SmallMat::zeros(b, b); // old block contributes nothing
            }
        }
    }
    panic!("normalize_block: persistent rank deficiency");
}

/// Max |VᵢᵀVⱼ - δᵢⱼ| over all basis blocks — test/diagnostic invariant.
pub fn orthonormality_error(blocks: &[&TasMatrix]) -> f64 {
    if blocks.is_empty() {
        return 0.0;
    }
    let mut worst = 0.0f64;
    for (i, x) in blocks.iter().enumerate() {
        let g = mv_trans_mv(1.0, blocks, x);
        let row_off: usize = blocks[..i].iter().map(|m| m.n_cols).sum();
        for r in 0..g.rows {
            for c in 0..x.n_cols {
                let expect = if r == row_off + c { 1.0 } else { 0.0 };
                worst = worst.max((g.at(r, c) - expect).abs());
            }
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::DenseCtx;

    #[test]
    fn normalize_gives_orthonormal_columns() {
        for em in [false, true] {
            let ctx = if em {
                DenseCtx::em_for_tests(64)
            } else {
                DenseCtx::mem_for_tests(64)
            };
            let x = TasMatrix::from_fn(&ctx, 300, 3, |r, c| {
                ((r * (c + 1)) % 17) as f64 - 8.0 + 0.1 * c as f64
            });
            let before = x.to_colmajor();
            let (r, replaced) = normalize_block(&x, &[], 1);
            assert!(!replaced);
            assert!(orthonormality_error(&[&x]) < 1e-12);
            // X_old = X_new R.
            let xnew = x.to_colmajor();
            let n = 300;
            for j in 0..3 {
                for i in 0..n {
                    let mut acc = 0.0;
                    for k in 0..3 {
                        acc += xnew[k * n + i] * r.at(k, j);
                    }
                    assert!(
                        (acc - before[j * n + i]).abs() < 1e-9,
                        "reconstruction ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn ortho_against_makes_blocks_orthogonal() {
        let ctx = DenseCtx::mem_for_tests(64);
        let v = TasMatrix::from_fn(&ctx, 200, 2, |r, c| ((r + c * 3) % 7) as f64);
        normalize_block(&v, &[], 2);
        let x = TasMatrix::from_fn(&ctx, 200, 2, |r, c| ((r * 2 + c) % 5) as f64 + 0.3);
        ortho_against(&[&v], &x);
        let g = mv_trans_mv(1.0, &[&v], &x);
        assert!(g.data.iter().all(|&e| e.abs() < 1e-12), "VᵀX != 0: {:?}", g.data);
        normalize_block(&x, &[&v], 3);
        assert!(orthonormality_error(&[&v, &x]) < 1e-12);
    }

    #[test]
    fn rank_deficient_block_gets_replaced() {
        let ctx = DenseCtx::mem_for_tests(64);
        // Two identical columns → rank 1.
        let x = TasMatrix::from_fn(&ctx, 150, 2, |r, _| (r % 13) as f64 + 1.0);
        let (_r, replaced) = normalize_block(&x, &[], 7);
        assert!(replaced);
        assert!(orthonormality_error(&[&x]) < 1e-10);
    }
}
