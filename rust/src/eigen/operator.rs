//! Linear operators: the `A·X` the eigensolver applies each iteration.
//!
//! `SpmmOperator` wraps a (symmetric) sparse matrix image and performs
//! ConvLayout → SpMM → ConvLayout, exactly the paper's data path: the
//! subspace lives column-major (on SSDs in EM mode), SpMM wants row-major
//! in RAM (§3.4's `ConvLayout`).  The eager `apply` materializes that
//! chain as three full-height dense matrices; `apply_streamed` (and the
//! lower-level [`Operator::streamed_producer`]) instead runs the fused
//! interval-granular boundary of [`crate::spmm::StreamedSpmm`], where
//! input intervals are gathered on demand and finished output row
//! intervals flow straight into the consuming pipeline walk.
//! `GramOperator` applies `Aᵀ(A·X)` for singular value decomposition of
//! directed graphs (§4.3.2); its streamed producer chains **two** hops
//! ([`crate::spmm::ChainedGramSpmm`]) through a bounded staging ring so
//! the intermediate `A·X` never materializes at full height either.
//!
//! Both streamed boundaries read SEM tile-row images through the
//! unified interval-stream scheduler ([`crate::safs::WalkScheduler`],
//! instantiated in [`crate::spmm::stream`]): up to
//! [`crate::safs::SafsConfig::read_ahead`] interval reads stay in
//! flight per worker (hop 1 of the Gram chain prefetches the next
//! interval the `Aᵀ` tile-column structure will demand), overlapping
//! SSD latency with multiplication exactly like the eager engine's
//! partition pipeline and the fused dense walks, which ride the same
//! scheduler — same bytes, same bits, lower `io_wait`.
//!
//! **Cross-apply image residency.**  The solver applies one operator
//! once per expansion step, and consecutive applies walk the same tile
//! rows in the same order — so every apply (streamed scheduler and
//! eager partition pipeline alike) shares the matrix filesystem's
//! [`crate::safs::ImageCache`] handle: SEM image ranges are probed
//! there before any `IoTicket` is issued and finished images are
//! published back under the [`crate::safs::SafsConfig::image_cache_bytes`]
//! budget.  With a budget of at least one image, warm applies re-read
//! zero image bytes and steady-state image traffic drops from
//! O(iterations × image) to O(image); with less, the cache pins a
//! stable prefix of the walk by next-use distance.  Caching moves
//! *when/whether* bytes are read, never what is computed — results are
//! bitwise identical at every budget (default 0 = off).

use crate::dense::{
    conv_layout_from_rowmajor, conv_layout_to_rowmajor, DenseCtx, FusedPipeline,
    IntervalProducer, TasMatrix,
};
use crate::metrics::{Counter, MemGuard, PhaseTimers};
use crate::sparse::SparseMatrix;
use crate::spmm::{spmm, ChainedGramSpmm, SpmmOpts, StreamedSpmm};
use std::sync::Arc;

pub trait Operator: Sync {
    fn dim(&self) -> usize;
    /// `Y = A·X` (returns a fresh TAS matrix in `ctx`'s backing mode).
    fn apply(&self, ctx: &Arc<DenseCtx>, x: &TasMatrix) -> TasMatrix;
    fn applies(&self) -> u64;

    /// Solver yield point: called when the caller enters a phase that
    /// performs no operator applies for a while (restart bookkeeping,
    /// final residual refinement).  A multi-tenant batched operator
    /// ([`crate::spmm::BatchedOperator`]) uses this to step out of the
    /// sweep barrier so co-resident jobs are not stalled behind a
    /// non-applying member; for ordinary solo operators it is a no-op.
    fn notify_idle(&self) {}

    /// Streamed operator boundary (§3.4): a producer that computes `A·x`
    /// one output row interval at a time for
    /// [`FusedPipeline::source`], gathering `x`'s intervals on
    /// demand.  `None` when the operator or layout cannot stream —
    /// callers fall back to [`Operator::apply`].  A returned producer
    /// counts as one operator application.
    fn streamed_producer<'a>(
        &'a self,
        x: &'a TasMatrix,
    ) -> Option<Box<dyn IntervalProducer + 'a>> {
        let _ = x;
        None
    }

    /// `Y = A·X` through the streamed boundary: the SpMM output flows
    /// interval-by-interval into `Y`'s storage with no intermediate
    /// full-height materialization.  Falls back to the eager
    /// [`Operator::apply`] when streaming is unavailable — including
    /// when `x` lives in a different context than the output (the
    /// producer derives interval geometry from `x`, so the walk's
    /// intervals must match).
    fn apply_streamed(&self, ctx: &Arc<DenseCtx>, x: &TasMatrix) -> TasMatrix {
        if !Arc::ptr_eq(ctx, x.ctx()) {
            return self.apply(ctx, x);
        }
        match self.streamed_producer(x) {
            Some(p) => {
                let y = TasMatrix::zeros_for_overwrite(ctx, self.dim(), x.n_cols);
                let mut pipe = FusedPipeline::new(ctx);
                pipe.source(&y, p);
                pipe.materialize();
                y
            }
            None => self.apply(ctx, x),
        }
    }
}

/// `A·X` via the SpMM engine.  The matrix must be symmetric for
/// eigensolving (undirected graphs); use [`GramOperator`] otherwise.
pub struct SpmmOperator {
    pub matrix: SparseMatrix,
    pub opts: SpmmOpts,
    pub threads: usize,
    pub timers: Arc<PhaseTimers>,
    count: Counter,
}

impl SpmmOperator {
    pub fn new(matrix: SparseMatrix, opts: SpmmOpts, threads: usize) -> SpmmOperator {
        assert_eq!(matrix.n_rows, matrix.n_cols, "eigenproblem needs square A");
        SpmmOperator {
            matrix,
            opts,
            threads,
            timers: Arc::new(PhaseTimers::new()),
            count: Counter::default(),
        }
    }
}

impl Operator for SpmmOperator {
    fn dim(&self) -> usize {
        self.matrix.n_rows as usize
    }

    fn apply(&self, ctx: &Arc<DenseCtx>, x: &TasMatrix) -> TasMatrix {
        self.count.inc();
        let input = self.timers.scope("conv_layout", || {
            conv_layout_to_rowmajor(x, self.matrix.tile_dim, self.opts.numa)
        });
        let _mg_in = MemGuard::new(&ctx.mem, (input.n_rows * input.n_cols * 8) as u64);
        let mut output = crate::spmm::DenseBlock::new(
            self.matrix.n_rows as usize,
            x.n_cols,
            self.matrix.tile_dim,
            self.opts.numa,
        );
        let _mg_out = MemGuard::new(&ctx.mem, (output.n_rows * output.n_cols * 8) as u64);
        self.timers.scope("spmm", || {
            spmm(&self.matrix, &input, &mut output, &self.opts, self.threads)
        });
        self.timers
            .scope("conv_layout", || conv_layout_from_rowmajor(ctx, &output))
    }

    fn applies(&self) -> u64 {
        self.count.get()
    }

    fn streamed_producer<'a>(
        &'a self,
        x: &'a TasMatrix,
    ) -> Option<Box<dyn IntervalProducer + 'a>> {
        let s = StreamedSpmm::new(&self.matrix, x, self.opts.vectorize)?;
        self.count.inc();
        Some(Box::new(s))
    }
}

/// How the CSR baseline operator multiplies (models the comparators of
/// §4: Trilinos traverses the matrix once per dense column; "MKL-like"
/// is a straightforward row-parallel CSR SpMM).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CsrMode {
    TrilinosLike,
    MklLike,
}

/// `A·X` via a CSR baseline kernel — used by the Fig. 12 comparison as
/// the "original Trilinos KrylovSchur" stand-in.
pub struct CsrOperator {
    pub csr: crate::sparse::CsrMatrix,
    pub mode: CsrMode,
    pub threads: usize,
    pub timers: Arc<PhaseTimers>,
    count: Counter,
}

impl CsrOperator {
    pub fn new(csr: crate::sparse::CsrMatrix, mode: CsrMode, threads: usize) -> CsrOperator {
        assert_eq!(csr.n_rows, csr.n_cols);
        CsrOperator {
            csr,
            mode,
            threads,
            timers: Arc::new(PhaseTimers::new()),
            count: Counter::default(),
        }
    }
}

impl Operator for CsrOperator {
    fn dim(&self) -> usize {
        self.csr.n_rows as usize
    }

    fn apply(&self, ctx: &Arc<DenseCtx>, x: &TasMatrix) -> TasMatrix {
        self.count.inc();
        let input = self
            .timers
            .scope("conv_layout", || conv_layout_to_rowmajor(x, 16, true));
        let _mg_in = MemGuard::new(&ctx.mem, (input.n_rows * input.n_cols * 8) as u64);
        let mut output =
            crate::spmm::DenseBlock::new(self.dim(), x.n_cols, 16, true);
        let _mg_out = MemGuard::new(&ctx.mem, (output.n_rows * output.n_cols * 8) as u64);
        self.timers.scope("spmm", || match self.mode {
            CsrMode::TrilinosLike => {
                crate::spmm::spmm_trilinos_like(&self.csr, &input, &mut output, self.threads)
            }
            CsrMode::MklLike => {
                crate::spmm::spmm_csr(&self.csr, &input, &mut output, self.threads, true)
            }
        });
        self.timers
            .scope("conv_layout", || conv_layout_from_rowmajor(ctx, &output))
    }

    fn applies(&self) -> u64 {
        self.count.get()
    }
}

/// `AᵀA·X` — the normal-equations operator whose eigenpairs give the
/// singular values/right singular vectors of a (rectangular or
/// unsymmetric) A.
///
/// The eager [`Operator::apply`] materializes **four** full-height dense
/// matrices (row-major input, `A·X`, `Aᵀ(A·X)`, and the output TAS
/// conversion); [`Operator::streamed_producer`] instead chains two
/// streamed hops through the bounded staging ring of
/// [`crate::spmm::ChainedGramSpmm`], so only the gathered input is ever
/// full-height resident.
pub struct GramOperator {
    pub a: SparseMatrix,
    pub at: SparseMatrix,
    pub opts: SpmmOpts,
    pub threads: usize,
    pub timers: Arc<PhaseTimers>,
    count: Counter,
}

impl GramOperator {
    pub fn new(a: SparseMatrix, at: SparseMatrix, opts: SpmmOpts, threads: usize) -> GramOperator {
        assert_eq!(a.n_rows, at.n_cols);
        assert_eq!(a.n_cols, at.n_rows);
        GramOperator {
            a,
            at,
            opts,
            threads,
            timers: Arc::new(PhaseTimers::new()),
            count: Counter::default(),
        }
    }
}

impl Operator for GramOperator {
    fn dim(&self) -> usize {
        self.a.n_cols as usize
    }

    fn apply(&self, ctx: &Arc<DenseCtx>, x: &TasMatrix) -> TasMatrix {
        self.count.inc();
        let input = self.timers.scope("conv_layout", || {
            conv_layout_to_rowmajor(x, self.a.tile_dim, self.opts.numa)
        });
        let _mg_in = MemGuard::new(&ctx.mem, (input.n_rows * input.n_cols * 8) as u64);
        let mut mid = crate::spmm::DenseBlock::new(
            self.a.n_rows as usize,
            x.n_cols,
            self.a.tile_dim,
            self.opts.numa,
        );
        let _mg_mid = MemGuard::new(&ctx.mem, (mid.n_rows * mid.n_cols * 8) as u64);
        self.timers
            .scope("spmm", || spmm(&self.a, &input, &mut mid, &self.opts, self.threads));
        let mut out = crate::spmm::DenseBlock::new(
            self.at.n_rows as usize,
            x.n_cols,
            self.at.tile_dim,
            self.opts.numa,
        );
        let _mg_out = MemGuard::new(&ctx.mem, (out.n_rows * out.n_cols * 8) as u64);
        self.timers
            .scope("spmm", || spmm(&self.at, &mid, &mut out, &self.opts, self.threads));
        self.timers
            .scope("conv_layout", || conv_layout_from_rowmajor(ctx, &out))
    }

    fn applies(&self) -> u64 {
        self.count.get()
    }

    fn streamed_producer<'a>(
        &'a self,
        x: &'a TasMatrix,
    ) -> Option<Box<dyn IntervalProducer + 'a>> {
        // The staging ring is group_size intervals; a SEM-backed A whose
        // intermediate exceeds it still streams while the re-read
        // schedule stays within the eager fallback's image total
        // (ChainedGramSpmm::new models it from the tile-column index).
        let cap = x.ctx().group_size.max(1);
        let s = ChainedGramSpmm::new(&self.a, &self.at, x, cap, self.opts.vectorize)?;
        self.count.inc();
        Some(Box::new(s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{build_mem, CooMatrix};
    use crate::util::prop::assert_close;

    #[test]
    fn spmm_operator_matches_dense() {
        // Symmetric 5-vertex graph.
        let mut coo = CooMatrix::new(5, 5);
        for &(r, c) in &[(0u32, 1u32), (1, 2), (2, 3), (3, 4), (0, 4)] {
            coo.push(r, c);
        }
        coo.symmetrize();
        let op = SpmmOperator::new(build_mem(&coo), SpmmOpts::default(), 2);
        let ctx = DenseCtx::mem_for_tests(64);
        let x = TasMatrix::from_fn(&ctx, 5, 2, |r, c| (r + 1) as f64 * (c + 1) as f64);
        let y = op.apply(&ctx, &x);
        // dense reference
        let xv = x.to_colmajor();
        let mut expect = vec![0.0; 10];
        for &(r, c) in &coo.entries {
            for j in 0..2 {
                expect[j * 5 + r as usize] += xv[j * 5 + c as usize];
            }
        }
        assert_close(&y.to_colmajor(), &expect, 1e-12, 1e-12, "op").unwrap();
        assert_eq!(op.applies(), 1);
    }

    #[test]
    fn apply_streamed_matches_eager_apply() {
        use crate::sparse::{build_matrix_opts, BuildTarget};
        use crate::util::rng::Rng;
        let mut rng = Rng::new(55);
        let mut coo = CooMatrix::new(300, 300);
        for _ in 0..2000 {
            coo.push(rng.gen_range(300) as u32, rng.gen_range(300) as u32);
        }
        coo.symmetrize();
        for em in [false, true] {
            let ctx = if em {
                DenseCtx::em_for_tests(64)
            } else {
                DenseCtx::mem_for_tests(64)
            };
            // tile 32 divides the 64-row intervals → the layout streams.
            let m = build_matrix_opts(&coo, 32, BuildTarget::Mem, true);
            let op = SpmmOperator::new(m, SpmmOpts::default(), 2);
            let x = TasMatrix::from_fn(&ctx, 300, 2, |r, c| ((r * 3 + c) % 13) as f64 - 6.0);
            let eager = op.apply(&ctx, &x);
            let streamed = op.apply_streamed(&ctx, &x);
            assert_close(
                &streamed.to_colmajor(),
                &eager.to_colmajor(),
                0.0,
                0.0,
                "streamed apply",
            )
            .unwrap();
            assert_eq!(op.applies(), 2, "producer counts as an apply");
        }
    }

    #[test]
    fn apply_streamed_falls_back_on_unaligned_layout() {
        let mut coo = CooMatrix::new(50, 50);
        for v in 0..50u32 {
            coo.push(v, (v + 1) % 50);
        }
        coo.symmetrize();
        let ctx = DenseCtx::mem_for_tests(96); // 96 % 64 != 0 → no stream
        let op = SpmmOperator::new(
            crate::sparse::build_matrix_opts(&coo, 64, crate::sparse::BuildTarget::Mem, true),
            SpmmOpts::default(),
            1,
        );
        let x = TasMatrix::from_fn(&ctx, 50, 2, |r, c| (r + c) as f64);
        let eager = op.apply(&ctx, &x);
        let streamed = op.apply_streamed(&ctx, &x); // falls back to eager
        assert_close(
            &streamed.to_colmajor(),
            &eager.to_colmajor(),
            0.0,
            0.0,
            "fallback",
        )
        .unwrap();
    }

    #[test]
    fn gram_apply_streamed_matches_eager_apply() {
        use crate::sparse::{build_matrix_opts, BuildTarget};
        use crate::util::rng::Rng;
        let mut rng = Rng::new(57);
        let mut coo = CooMatrix::new(320, 320);
        for _ in 0..2200 {
            coo.push(rng.gen_range(320) as u32, rng.gen_range(320) as u32);
        }
        coo.sort_dedup();
        let at_coo = coo.transpose();
        for em in [false, true] {
            let ctx = if em {
                DenseCtx::em_for_tests(64)
            } else {
                DenseCtx::mem_for_tests(64)
            };
            // tile 32 divides the 64-row intervals → the two-hop streams.
            let a = build_matrix_opts(&coo, 32, BuildTarget::Mem, true);
            let at = build_matrix_opts(&at_coo, 32, BuildTarget::Mem, true);
            let op = GramOperator::new(a, at, SpmmOpts::default(), 2);
            let x = TasMatrix::from_fn(&ctx, 320, 2, |r, c| ((r * 7 + c) % 19) as f64 - 9.0);
            let eager = op.apply(&ctx, &x);
            let streamed = op.apply_streamed(&ctx, &x);
            assert_close(
                &streamed.to_colmajor(),
                &eager.to_colmajor(),
                0.0,
                0.0,
                "streamed two-hop apply",
            )
            .unwrap();
            assert_eq!(op.applies(), 2, "producer counts as an apply");
        }
    }

    #[test]
    fn gram_apply_streamed_falls_back_on_unaligned_layout() {
        let mut coo = CooMatrix::new(60, 60);
        for v in 0..60u32 {
            coo.push(v, (v + 7) % 60);
        }
        coo.sort_dedup();
        let at_coo = coo.transpose();
        let ctx = DenseCtx::mem_for_tests(96); // 96 % 64 != 0 → no stream
        let a = crate::sparse::build_matrix_opts(&coo, 64, crate::sparse::BuildTarget::Mem, true);
        let at =
            crate::sparse::build_matrix_opts(&at_coo, 64, crate::sparse::BuildTarget::Mem, true);
        let op = GramOperator::new(a, at, SpmmOpts::default(), 1);
        let x = TasMatrix::from_fn(&ctx, 60, 2, |r, c| (r + 2 * c) as f64);
        let eager = op.apply(&ctx, &x);
        let streamed = op.apply_streamed(&ctx, &x); // falls back to eager
        assert_close(
            &streamed.to_colmajor(),
            &eager.to_colmajor(),
            0.0,
            0.0,
            "gram fallback",
        )
        .unwrap();
    }

    #[test]
    fn gram_operator_is_ata() {
        let mut coo = CooMatrix::new(4, 4);
        for &(r, c) in &[(0u32, 1u32), (1, 2), (3, 0), (2, 2)] {
            coo.push(r, c);
        }
        coo.sort_dedup();
        let a = build_mem(&coo);
        let at = build_mem(&coo.transpose());
        let op = GramOperator::new(a, at, SpmmOpts::default(), 1);
        let ctx = DenseCtx::mem_for_tests(64);
        let x = TasMatrix::from_fn(&ctx, 4, 1, |r, _| r as f64 + 1.0);
        let y = op.apply(&ctx, &x);
        // Dense AᵀA x.
        let mut ad = vec![vec![0.0f64; 4]; 4];
        for &(r, c) in &coo.entries {
            ad[r as usize][c as usize] = 1.0;
        }
        let xv = x.to_colmajor();
        let mut ax = vec![0.0; 4];
        for r in 0..4 {
            for c in 0..4 {
                ax[r] += ad[r][c] * xv[c];
            }
        }
        let mut expect = vec![0.0; 4];
        for r in 0..4 {
            for c in 0..4 {
                expect[c] += ad[r][c] * ax[r];
            }
        }
        assert_close(&y.to_colmajor(), &expect, 1e-12, 1e-12, "ata").unwrap();
    }
}
