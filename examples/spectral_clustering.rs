//! Spectral clustering (the paper's motivating application, §1): embed a
//! stochastic-block-model graph with the top eigenvectors of its
//! adjacency matrix, cluster the embedding with k-means, and measure the
//! recovered community structure against ground truth.
//!
//! ```bash
//! cargo run --release --example spectral_clustering
//! ```

use flasheigen::dense::DenseCtx;
use flasheigen::eigen::{solve, EigenConfig, SpmmOperator, Which};
use flasheigen::safs::{Safs, SafsConfig};
use flasheigen::sparse::{build_matrix, BuildTarget, CooMatrix};
use flasheigen::spmm::SpmmOpts;
use flasheigen::util::rng::Rng;

/// Stochastic block model: `k` communities of `size` vertices; edge
/// probability `p_in` within and `p_out` across communities.
fn sbm(k: usize, size: usize, p_in: f64, p_out: f64, rng: &mut Rng) -> CooMatrix {
    let n = (k * size) as u64;
    let mut coo = CooMatrix::new(n, n);
    // Sparse sampling: expected degrees are small, so sample neighbors
    // per vertex rather than all pairs.
    for v in 0..n {
        let comm = v as usize / size;
        let d_in = (p_in * size as f64) as usize;
        let d_out = (p_out * (n as usize - size) as f64) as usize;
        for _ in 0..d_in {
            let u = (comm * size) as u64 + rng.gen_range(size as u64);
            if u != v {
                coo.push(v as u32, u as u32);
            }
        }
        for _ in 0..d_out {
            let u = rng.gen_range(n);
            if u as usize / size != comm {
                coo.push(v as u32, u as u32);
            }
        }
    }
    coo.symmetrize();
    coo
}

/// k-means on rows of an n×d embedding (a few Lloyd iterations).
fn kmeans(data: &[f64], n: usize, d: usize, k: usize, rng: &mut Rng) -> Vec<usize> {
    let mut centers: Vec<f64> = (0..k)
        .flat_map(|_| {
            let r = rng.gen_usize(n);
            data[r * d..(r + 1) * d].to_vec()
        })
        .collect();
    let mut assign = vec![0usize; n];
    for _iter in 0..25 {
        // Assign.
        for i in 0..n {
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..k {
                let dist: f64 = (0..d)
                    .map(|j| (data[i * d + j] - centers[c * d + j]).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            assign[i] = best.1;
        }
        // Update.
        let mut sums = vec![0.0; k * d];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            counts[assign[i]] += 1;
            for j in 0..d {
                sums[assign[i] * d + j] += data[i * d + j];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for j in 0..d {
                    centers[c * d + j] = sums[c * d + j] / counts[c] as f64;
                }
            }
        }
    }
    assign
}

/// Clustering accuracy under the best label permutation (k small).
fn accuracy(assign: &[usize], truth: &[usize], k: usize) -> f64 {
    let mut perm: Vec<usize> = (0..k).collect();
    let mut best = 0usize;
    // Heap's algorithm over permutations (k ≤ 4 here).
    fn permute(
        perm: &mut Vec<usize>,
        l: usize,
        assign: &[usize],
        truth: &[usize],
        best: &mut usize,
    ) {
        if l == perm.len() {
            let correct = assign
                .iter()
                .zip(truth)
                .filter(|&(&a, &t)| perm[a] == t)
                .count();
            *best = (*best).max(correct);
            return;
        }
        for i in l..perm.len() {
            perm.swap(l, i);
            permute(perm, l + 1, assign, truth, best);
            perm.swap(l, i);
        }
    }
    permute(&mut perm, 0, assign, truth, &mut best);
    best as f64 / assign.len() as f64
}

fn main() {
    let mut rng = Rng::new(123);
    let (k, size) = (3usize, 4000usize);
    let coo = sbm(k, size, 0.004, 0.0004, &mut rng);
    let n = coo.n_rows as usize;
    println!("SBM: {k} communities × {size} vertices, |E|={}", coo.nnz());

    // Eigendecompose on the simulated SSD array (SEM mode).
    let fs = Safs::new(SafsConfig::default());
    let matrix = build_matrix(&coo, 4096, BuildTarget::Safs(&fs, "sbm"));
    let ctx = DenseCtx::new(fs, true);
    // Select the §3.4 path explicitly rather than inheriting the context
    // default: fused MultiVec pipelines + the streamed operator boundary
    // (which IS the default — pass `--eager` style opt-out by calling
    // `ctx.set_eager(true)` to ablate against the Table-1 reference ops).
    ctx.set_fused(true);
    ctx.set_streamed(true);
    println!(
        "dense path: {} multivec, {} operator boundary",
        if ctx.is_fused() { "fused" } else { "eager" },
        if ctx.is_streamed() { "streamed" } else { "materialized" }
    );
    let op = SpmmOperator::new(matrix, SpmmOpts::default(), 4);
    let cfg = EigenConfig {
        nev: k,
        block_size: k,
        num_blocks: 10,
        tol: 1e-7,
        max_restarts: 300,
        which: Which::LargestAlgebraic,
        seed: 5,
        compute_eigenvectors: true,
        refine_steps: 0,
    };
    let res = solve(&op, &ctx, &cfg);
    println!(
        "top-{} eigenvalues: {:?} (converged={})",
        k, res.eigenvalues, res.converged
    );

    // Embed: rows of the Ritz-vector block.
    let blocks = res.eigenvectors.expect("eigenvectors");
    let mut embedding = vec![0.0; n * k];
    let mut col = 0usize;
    for b in &blocks {
        let cm = b.to_colmajor();
        for j in 0..b.n_cols {
            for i in 0..n {
                embedding[i * k + col + j] = cm[j * n + i];
            }
        }
        col += b.n_cols;
    }

    let assign = kmeans(&embedding, n, k, k, &mut rng);
    let truth: Vec<usize> = (0..n).map(|v| v / size).collect();
    let acc = accuracy(&assign, &truth, k);
    println!("clustering accuracy vs planted communities: {:.1}%", 100.0 * acc);
    assert!(acc > 0.9, "spectral clustering should recover the SBM communities");
}
