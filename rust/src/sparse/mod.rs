//! Sparse-matrix formats: the FlashEigen tile image (SCSR+COO, §3.3.1)
//! and the CSR baseline.

pub mod builder;
pub mod csr;
pub mod delta;
pub mod matrix;
pub mod tile;

pub use builder::{build_matrix, build_matrix_opts, build_mem, BuildTarget, CooMatrix};
pub use csr::CsrMatrix;
pub use delta::{DeltaBatch, DeltaOverlay, DeltaStats};
pub use matrix::{SparseMatrix, Storage, TileRowMeta, TileRowView};
pub use tile::{TileValues, TileView, DEFAULT_TILE_DIM, MAX_TILE_DIM};
