//! Minimal JSON reader/writer.
//!
//! `serde`/`serde_json` are not available offline, and the only structured
//! interchange we need is the artifact manifest written by
//! `python/compile/aot.py` plus machine-readable benchmark reports.  This
//! module implements a small, strict JSON value model that covers that.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Numbers are kept as `f64` (the manifest only holds small
/// integers and paths, so this is lossless for our use).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Parse a JSON document.  Strict: trailing garbage is an error.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Convenience constructors for report emission.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn int(n: i64) -> Json {
        Json::Num(n as f64)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    let len = match c {
                        0x00..=0x7F => 0,
                        0xC0..=0xDF => 1,
                        0xE0..=0xEF => 2,
                        0xF0..=0xF7 => 3,
                        _ => return Err(self.err("bad utf-8")),
                    };
                    let start = self.pos - 1;
                    for _ in 0..len {
                        self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                    }
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, &mut s);
        f.write_str(&s)
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape_into(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
            "artifacts": [
                {"op": "tsgemm", "ri": 16384, "m": 16, "b": 4, "path": "tsgemm_ri16384_m16_b4.hlo.txt"},
                {"op": "gram", "ri": 16384, "m": 16, "b": 4, "path": "gram_ri16384_m16_b4.hlo.txt"}
            ],
            "version": 1
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().as_i64(), Some(1));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[0].get("op").unwrap().as_str(), Some("tsgemm"));
        assert_eq!(arts[0].get("ri").unwrap().as_usize(), Some(16384));
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::int(3)),
            ("b", Json::arr(vec![Json::num(1.5), Json::str("x\"y"), Json::Null])),
            ("c", Json::Bool(true)),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(Json::parse("-3.25e2").unwrap().as_f64(), Some(-325.0));
        assert_eq!(Json::parse("0").unwrap().as_i64(), Some(0));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\nbé😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nbé😀"));
    }

    #[test]
    fn parses_nested_utf8() {
        let v = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo wörld"));
    }
}
