//! A SAFS file: striped, lazily-grown, in-memory byte store whose accesses
//! are timed against the simulated devices that own each stripe block.
//!
//! Data lives with the file (the devices model timing and wear only); all
//! reads/writes split along stripe blocks and additionally along
//! `max_io_size` (the kernel's maximal request size, Fig. 9), reserving
//! service time on the owning device per sub-request.  The returned
//! [`Instant`] is the simulated completion deadline of the whole range.

use super::array::SsdArray;
use super::stripe::StripeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Monotonic generation counter behind [`SafsFile::uid`].
static NEXT_UID: AtomicU64 = AtomicU64::new(1);

pub struct SafsFile {
    pub name: String,
    /// Unique identity of this file *incarnation*, monotonic across all
    /// [`SafsFile::new`] calls in the process.  Re-creating (truncating)
    /// a file at the same path yields a handle with the same name but a
    /// larger uid — the [`crate::safs::ImageCache`] tags entries with it
    /// so an in-flight reader holding a pre-truncation handle (e.g.
    /// across a delta compaction) can never publish, or be served, the
    /// old incarnation's bytes under the new one's key.
    pub uid: u64,
    pub stripe: StripeMap,
    /// Stripe blocks, grown on demand.  Each block is independently locked
    /// so concurrent workers touching different blocks do not contend.
    blocks: RwLock<Vec<Arc<Mutex<Box<[u8]>>>>>,
    /// Logical file size = highest byte written + 1.
    size: AtomicU64,
    /// Lifetime device bytes read from / written to this file, recorded
    /// at the same [`SafsFile::reserve_range`] chokepoint as the global
    /// per-device ledger — so summing per-file counters over all files
    /// reproduces the array totals exactly.  This is what lets the
    /// resident solver service attribute shared-array traffic to
    /// individual jobs by file-name prefix (see
    /// [`crate::safs::Safs::file_bytes`]).
    stat_read: AtomicU64,
    stat_written: AtomicU64,
}

impl SafsFile {
    pub fn new(name: &str, stripe: StripeMap) -> SafsFile {
        SafsFile {
            name: name.to_string(),
            uid: NEXT_UID.fetch_add(1, Ordering::Relaxed),
            stripe,
            blocks: RwLock::new(Vec::new()),
            size: AtomicU64::new(0),
            stat_read: AtomicU64::new(0),
            stat_written: AtomicU64::new(0),
        }
    }

    /// Lifetime device bytes read from this file (accounted at
    /// [`SafsFile::reserve_range`], like the array ledger).
    pub fn bytes_read(&self) -> u64 {
        self.stat_read.load(Ordering::Relaxed)
    }

    /// Lifetime device bytes written to this file.
    pub fn bytes_written(&self) -> u64 {
        self.stat_written.load(Ordering::Relaxed)
    }

    pub fn size(&self) -> u64 {
        self.size.load(Ordering::Acquire)
    }

    /// Bytes of storage currently allocated (all touched stripe blocks).
    pub fn allocated(&self) -> u64 {
        (self.blocks.read().unwrap().len() * self.stripe.block_size) as u64
    }

    fn block(&self, idx: usize) -> Arc<Mutex<Box<[u8]>>> {
        {
            let blocks = self.blocks.read().unwrap();
            if idx < blocks.len() {
                return blocks[idx].clone();
            }
        }
        let mut blocks = self.blocks.write().unwrap();
        while blocks.len() <= idx {
            blocks.push(Arc::new(Mutex::new(
                vec![0u8; self.stripe.block_size].into_boxed_slice(),
            )));
        }
        blocks[idx].clone()
    }

    /// Reserve device service time for the whole range — **timing and
    /// accounting only**, no data moves.  Returns the simulated
    /// completion deadline (max over the per-device sub-requests).
    ///
    /// The queued I/O engine calls this on the *submitting* thread so
    /// deadlines start at submission, then hands the matching
    /// [`SafsFile::transfer_read`]/[`SafsFile::transfer_write`] to its
    /// reactor; [`SafsFile::pread`]/[`SafsFile::pwrite`] compose the
    /// two for the synchronous backends.  Per-device byte/request
    /// counts are recorded here, identically for every backend.
    pub fn reserve_range(&self, array: &SsdArray, offset: u64, len: usize, write: bool) -> Instant {
        if write {
            self.stat_written.fetch_add(len as u64, Ordering::Relaxed);
        } else {
            self.stat_read.fetch_add(len as u64, Ordering::Relaxed);
        }
        let mut deadline = Instant::now();
        for (block_idx, _in_block, len, _in_buf) in self.stripe.split_range(offset, len) {
            let dev = array.device(self.stripe.device_for(block_idx));
            // Split each stripe chunk by the kernel's max request size.
            let mut done = 0usize;
            while done < len {
                let take = (len - done).min(array.cfg.max_io_size);
                let d = dev.reserve(&array.cfg, take, write);
                if d > deadline {
                    deadline = d;
                }
                done += take;
            }
        }
        deadline
    }

    /// Data-only write: memcpy `data` into the stripe blocks at
    /// `offset`.  No device time is reserved — pair with
    /// [`SafsFile::reserve_range`].
    pub fn transfer_write(&self, offset: u64, data: &[u8]) {
        for (block_idx, in_block, len, in_buf) in self.stripe.split_range(offset, data.len()) {
            let block = self.block(block_idx as usize);
            let mut guard = block.lock().unwrap();
            guard[in_block..in_block + len].copy_from_slice(&data[in_buf..in_buf + len]);
        }
        self.size
            .fetch_max(offset + data.len() as u64, Ordering::AcqRel);
    }

    /// Data-only read: memcpy the stripe blocks at `offset` into `buf`.
    /// Reading past the written size returns zeros (like a sparse
    /// file).  No device time is reserved — pair with
    /// [`SafsFile::reserve_range`].
    pub fn transfer_read(&self, offset: u64, buf: &mut [u8]) {
        for (block_idx, in_block, len, in_buf) in self.stripe.split_range(offset, buf.len()) {
            let block = self.block(block_idx as usize);
            let guard = block.lock().unwrap();
            buf[in_buf..in_buf + len].copy_from_slice(&guard[in_block..in_block + len]);
        }
    }

    /// Write `data` at `offset`, reserving device time; returns the
    /// simulated completion deadline.
    pub fn pwrite(&self, array: &SsdArray, offset: u64, data: &[u8]) -> Instant {
        let deadline = self.reserve_range(array, offset, data.len(), true);
        self.transfer_write(offset, data);
        deadline
    }

    /// Read `buf.len()` bytes from `offset` into `buf`; returns the
    /// simulated completion deadline.
    pub fn pread(&self, array: &SsdArray, offset: u64, buf: &mut [u8]) -> Instant {
        let deadline = self.reserve_range(array, offset, buf.len(), false);
        self.transfer_read(offset, buf);
        deadline
    }
}

/// Shared handle type used across the crate.
pub type FileHandle = Arc<SafsFile>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::safs::config::SafsConfig;

    fn mk() -> (SsdArray, SafsFile) {
        let mut cfg = SafsConfig::untimed();
        cfg.num_ssds = 4;
        cfg.stripe_block = 64;
        let array = SsdArray::new(cfg);
        let f = SafsFile::new("t", StripeMap::identity(4, 64));
        (array, f)
    }

    #[test]
    fn write_read_roundtrip_across_blocks() {
        let (array, f) = mk();
        let data: Vec<u8> = (0..500).map(|i| (i % 251) as u8).collect();
        f.pwrite(&array, 30, &data);
        let mut out = vec![0u8; 500];
        f.pread(&array, 30, &mut out);
        assert_eq!(out, data);
        assert_eq!(f.size(), 530);
    }

    #[test]
    fn unwritten_ranges_read_zero() {
        let (array, f) = mk();
        f.pwrite(&array, 0, &[7u8; 10]);
        let mut out = vec![1u8; 20];
        f.pread(&array, 5, &mut out);
        assert_eq!(&out[..5], &[7u8; 5]);
        assert_eq!(&out[5..], &[0u8; 15]);
    }

    #[test]
    fn traffic_spreads_across_devices() {
        let (array, f) = mk();
        let data = vec![1u8; 64 * 8];
        f.pwrite(&array, 0, &data);
        let stats = array.stats();
        // 8 stripe blocks over 4 devices round-robin: 2 blocks each.
        assert!((stats.skew() - 1.0).abs() < 1e-9, "skew {}", stats.skew());
        assert_eq!(stats.bytes_written, 64 * 8);
    }

    #[test]
    fn max_io_size_splits_requests() {
        let mut cfg = SafsConfig::untimed();
        cfg.num_ssds = 1;
        cfg.stripe_block = 1024;
        cfg.max_io_size = 100;
        let array = SsdArray::new(cfg);
        let f = SafsFile::new("t", StripeMap::identity(1, 1024));
        f.pwrite(&array, 0, &vec![0u8; 1000]);
        // 1000 bytes / 100-byte max IO = 10 device requests.
        assert_eq!(array.stats().write_reqs, 10);
    }

    #[test]
    fn reserve_and_transfer_split_matches_composed_path() {
        // reserve_range is timing/accounting-only; transfer_* are
        // data-only.  Their composition must equal pread/pwrite
        // request-for-request and byte-for-byte.
        let (array, f) = mk();
        let data: Vec<u8> = (0..300).map(|i| (i % 97) as u8).collect();
        f.reserve_range(&array, 10, data.len(), true);
        let s = array.stats();
        assert_eq!(s.bytes_written, 300);
        assert_eq!(f.size(), 0, "reserve_range must not move data");
        f.transfer_write(10, &data);
        assert_eq!(f.size(), 310);
        assert_eq!(array.stats().bytes_written, 300, "transfer_write must not account");
        let mut out = vec![0u8; 300];
        f.transfer_read(10, &mut out);
        assert_eq!(out, data);
        assert_eq!(array.stats().bytes_read, 0, "transfer_read must not account");
    }

    #[test]
    fn per_file_counters_track_the_array_ledger() {
        let (array, f) = mk();
        f.pwrite(&array, 0, &vec![3u8; 700]);
        let mut out = vec![0u8; 450];
        f.pread(&array, 100, &mut out);
        assert_eq!(f.bytes_written(), 700);
        assert_eq!(f.bytes_read(), 450);
        // Same chokepoint as the device ledger, so they agree exactly.
        let s = array.stats();
        assert_eq!(s.bytes_written, f.bytes_written());
        assert_eq!(s.bytes_read, f.bytes_read());
        // reserve_range accounts even without a transfer (the queued
        // engine's submission-side path).
        f.reserve_range(&array, 0, 50, false);
        assert_eq!(f.bytes_read(), 500);
    }

    #[test]
    fn recreated_files_get_strictly_larger_uids() {
        let (_, f1) = mk();
        let (_, f2) = mk();
        // Same name, new incarnation — the uid orders them.
        assert_eq!(f1.name, f2.name);
        assert!(f2.uid > f1.uid);
    }

    #[test]
    fn concurrent_disjoint_writes() {
        let (array, f) = mk();
        let f = Arc::new(f);
        std::thread::scope(|s| {
            for t in 0..4u8 {
                let f = f.clone();
                let array = &array;
                s.spawn(move || {
                    let data = vec![t + 1; 128];
                    f.pwrite(array, t as u64 * 128, &data);
                });
            }
        });
        let mut out = vec![0u8; 512];
        f.pread(&array, 0, &mut out);
        for t in 0..4usize {
            assert!(out[t * 128..(t + 1) * 128].iter().all(|&b| b == t as u8 + 1));
        }
    }
}
