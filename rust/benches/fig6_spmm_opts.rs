//! Figure 6: SpMM memory-optimization ablation (cumulative) on the
//! Twitter and Friendster graphs for several dense-matrix widths.
use flasheigen::graph::Dataset;
use flasheigen::harness::{fig6, BenchCfg};

fn main() {
    let mut cfg = BenchCfg::from_env();
    // SpMM cache behaviour needs graphs whose dense vectors exceed the
    // CPU caches; run these figures at 8x the default dataset scale.
    cfg.scale *= 8.0;
    eprintln!("fig6: scale={:.2e} threads={} dilation={}", cfg.scale, cfg.threads, cfg.dilation);
    fig6(&cfg, &[Dataset::Friendster, Dataset::Twitter], &[1, 4, 16]).print();
}
