//! The per-tile multiply kernels — the innermost loops of SpMM.
//!
//! For each nonzero `(r, c, v)` of a tile we do
//! `out[r, :] += v * in[c, :]` over the dense-matrix width `b`.  With the
//! vectorization optimization on, the width is monomorphized
//! (`B ∈ {1,2,4,8,16}`) so the compiler emits SIMD for the inner loop —
//! the Rust analogue of the paper's "predefine the matrix width in the
//! code" for GCC autovectorization.  The SCSR stream and the COO region
//! are iterated by separate loops; COO needs no end-of-row test per entry.
//!
//! # Precision contract
//!
//! Tile values are widened to f64 exactly once, as they are read from the
//! (possibly narrowed) stored image ([`crate::sparse::TileValues::get`]).
//! Every multiply-accumulate below — and everything downstream of it:
//! fused walks, CGS2, Rayleigh–Ritz — runs in f64 regardless of
//! [`crate::safs::StoragePrecision`].  Reduced storage precision
//! perturbs only the *inputs* (stored matrix/subspace values), so the
//! classical bound `‖fl(A)−A‖ ≤ u₃₂‖A‖` carries through to the residuals
//! checked by the precision test tier.

use crate::sparse::TileView;

/// Multiply one tile: `out_rows[r*b..] += v * in_rows[c*b..]`.
///
/// `in_rows` are the input-matrix rows for the tile's column range,
/// `out_rows` the output rows for the tile's row range, both row-major
/// with width `b`.
#[inline]
pub fn multiply_tile(
    view: &TileView,
    in_rows: &[f64],
    out_rows: &mut [f64],
    b: usize,
    vectorize: bool,
) {
    if vectorize {
        match b {
            1 => tile_kernel_fixed::<1>(view, in_rows, out_rows),
            2 => tile_kernel_fixed::<2>(view, in_rows, out_rows),
            4 => tile_kernel_fixed::<4>(view, in_rows, out_rows),
            8 => tile_kernel_fixed::<8>(view, in_rows, out_rows),
            16 => tile_kernel_fixed::<16>(view, in_rows, out_rows),
            _ => tile_kernel_dyn(view, in_rows, out_rows, b),
        }
    } else {
        tile_kernel_dyn(view, in_rows, out_rows, b)
    }
}

/// Width-monomorphized kernel: the inner loop has a compile-time trip
/// count, which rustc/LLVM unrolls and vectorizes.
fn tile_kernel_fixed<const B: usize>(view: &TileView, in_rows: &[f64], out_rows: &mut [f64]) {
    let weighted = !view.values.is_empty();
    let mut vi = 0usize;
    // SCSR region: rows with ≥2 entries (or all rows in SCSR-only images).
    let mut out_base = 0usize;
    for &w in view.scsr {
        if w & 0x8000 != 0 {
            out_base = (w & 0x7fff) as usize * B;
        } else {
            let v = if weighted { view.values.get(vi) } else { 1.0 };
            vi += 1;
            let inp = &in_rows[w as usize * B..w as usize * B + B];
            let out = &mut out_rows[out_base..out_base + B];
            for k in 0..B {
                out[k] += v * inp[k];
            }
        }
    }
    // COO region: single-entry rows, no end-of-row conditional.
    for pair in view.coo.chunks_exact(2) {
        let (r, c) = (pair[0] as usize, pair[1] as usize);
        let v = if weighted { view.values.get(vi) } else { 1.0 };
        vi += 1;
        let inp = &in_rows[c * B..c * B + B];
        let out = &mut out_rows[r * B..r * B + B];
        for k in 0..B {
            out[k] += v * inp[k];
        }
    }
}

/// Runtime-width kernel — the unvectorized baseline.
fn tile_kernel_dyn(view: &TileView, in_rows: &[f64], out_rows: &mut [f64], b: usize) {
    let weighted = !view.values.is_empty();
    let mut vi = 0usize;
    let mut out_base = 0usize;
    for &w in view.scsr {
        if w & 0x8000 != 0 {
            out_base = (w & 0x7fff) as usize * b;
        } else {
            let v = if weighted { view.values.get(vi) } else { 1.0 };
            vi += 1;
            let inp = &in_rows[w as usize * b..w as usize * b + b];
            let out = &mut out_rows[out_base..out_base + b];
            for k in 0..b {
                out[k] += v * inp[k];
            }
        }
    }
    for pair in view.coo.chunks_exact(2) {
        let (r, c) = (pair[0] as usize, pair[1] as usize);
        let v = if weighted { view.values.get(vi) } else { 1.0 };
        vi += 1;
        let inp = &in_rows[c * b..c * b + b];
        let out = &mut out_rows[r * b..r * b + b];
        for k in 0..b {
            out[k] += v * inp[k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::tile::{encode_tile, encode_tile_opts};

    fn dense_ref(
        entries: &[(u16, u16)],
        vals: Option<&[f64]>,
        in_rows: &[f64],
        b: usize,
        out_len: usize,
    ) -> Vec<f64> {
        let mut out = vec![0.0; out_len];
        for (i, &(r, c)) in entries.iter().enumerate() {
            let v = vals.map(|v| v[i]).unwrap_or(1.0);
            for k in 0..b {
                out[r as usize * b + k] += v * in_rows[c as usize * b + k];
            }
        }
        out
    }

    #[test]
    fn kernels_match_reference_all_widths() {
        let entries = [
            (0u16, 0u16),
            (0, 3),
            (1, 2),
            (3, 0),
            (3, 1),
            (3, 3),
            (5, 5),
            (7, 2),
        ];
        // Half-integer weights are exactly representable at both stored
        // widths, so the f32- and f64-width images must agree bitwise.
        let vals: Vec<f64> = (0..entries.len()).map(|i| i as f64 * 0.5 + 1.0).collect();
        for b in [1usize, 2, 3, 4, 8, 16] {
            let in_rows: Vec<f64> = (0..8 * b).map(|i| (i as f64).sin()).collect();
            for weighted in [false, true] {
                let vref = weighted.then_some(&vals[..]);
                let expect = dense_ref(&entries, vref, &in_rows, b, 8 * b);
                for coo_hybrid in [false, true] {
                    for value_elem in [4usize, 8] {
                        let bytes = encode_tile_opts(&entries, vref, 8, coo_hybrid, value_elem);
                        let view = TileView::parse(&bytes, if weighted { value_elem } else { 0 });
                        for vec in [false, true] {
                            let mut out = vec![0.0; 8 * b];
                            multiply_tile(&view, &in_rows, &mut out, b, vec);
                            assert_eq!(
                                out, expect,
                                "b={b} w={weighted} coo={coo_hybrid} e={value_elem} v={vec}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn accumulates_into_existing_output() {
        let bytes = encode_tile(&[(0, 0)], None, 4);
        let view = TileView::parse(&bytes, 0);
        let mut out = vec![10.0; 4];
        multiply_tile(&view, &[2.0, 0.0, 0.0, 0.0], &mut out, 1, true);
        assert_eq!(out, vec![12.0, 10.0, 10.0, 10.0]);
    }
}
