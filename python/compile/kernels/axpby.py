"""L1 Pallas kernel: fused elementwise AXPBY for MvAddMv.

``alpha * x + beta * y`` over one row interval (both operands in the
flat column-major layout, seen here as a 1-D array).  Trivial compute,
but it exercises the elementwise-kernel path end to end and fuses the
two scales and the add into a single memory pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 65536


def _kernel(ab_ref, x_ref, y_ref, o_ref):
    o_ref[...] = ab_ref[0] * x_ref[...] + ab_ref[1] * y_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def axpby(x, y, alpha, beta, *, block=DEFAULT_BLOCK):
    """Pallas fused ``alpha*x + beta*y`` over flat arrays."""
    (n,) = x.shape
    assert y.shape == (n,)
    if n % block != 0:
        block = n
    ab = jnp.stack(
        [jnp.asarray(alpha, x.dtype), jnp.asarray(beta, x.dtype)]
    ).reshape((2,))
    return pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(ab, x, y)
