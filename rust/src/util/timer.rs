//! Wall-clock timing helpers used by benches and the metrics layer.

use std::time::{Duration, Instant};

/// A simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.secs())
}

/// Run `f` `warmup + iters` times; return the mean seconds over the
/// measured iterations.  This is the micro-benchmark primitive used by the
/// `harness = false` bench binaries (criterion is unavailable offline).
pub fn bench_mean(warmup: usize, iters: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t = Timer::start();
    for _ in 0..iters.max(1) {
        f();
    }
    t.secs() / iters.max(1) as f64
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else if s < 7200.0 {
        format!("{:.1}min", s / 60.0)
    } else {
        format!("{:.2}h", s / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.secs() >= 0.004);
    }

    #[test]
    fn bench_mean_runs() {
        let mut count = 0usize;
        let mean = bench_mean(2, 3, || count += 1);
        assert_eq!(count, 5);
        assert!(mean >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-5).ends_with("us"));
        assert!(fmt_secs(5e-2).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
        assert!(fmt_secs(500.0).ends_with("min"));
        assert!(fmt_secs(50000.0).ends_with('h'));
    }
}
