//! The on-SSD sparse-matrix image (§3.3.1, Figure 2).
//!
//! Tiles are organised in **tile rows**; each tile row is one contiguous
//! byte range — the unit of I/O for semi-external-memory SpMM — and a
//! small in-RAM **matrix index** records where each tile row lives so
//! partitions can be fetched independently (and in parallel).
//!
//! Byte layout of one tile row (4-byte aligned):
//!
//! ```text
//! u32 ntiles, u32 pad
//! per tile: u32 tile_col, u32 payload_len, payload…(4-byte aligned)
//! ```

use super::delta::DeltaOverlay;
use super::tile::TileView;
use crate::safs::{FileHandle, Safs};
use std::sync::Arc;

/// Index entry for one tile row: where it lives in the image.
#[derive(Clone, Copy, Debug, Default)]
pub struct TileRowMeta {
    pub offset: u64,
    pub len: u32,
    pub nnz: u64,
}

/// Where the image bytes live.
pub enum Storage {
    /// Fully in memory (the FE-IM configurations).
    Mem(Arc<Vec<u8>>),
    /// On the SSD array behind SAFS (the FE-SEM configurations).
    Safs { fs: Arc<Safs>, file: FileHandle },
}

/// A sparse matrix in the FlashEigen tile format.
pub struct SparseMatrix {
    pub n_rows: u64,
    pub n_cols: u64,
    pub nnz: u64,
    pub tile_dim: usize,
    /// Stored width of the per-nonzero value region: 0 = unweighted, 4 =
    /// f32, 8 = f64 (f64-native weights under full-width storage
    /// precision).  Fixed at build time for the whole image.
    pub value_elem: usize,
    /// One entry per tile row; kept in RAM during multiplication (§3.3.1:
    /// "the matrix index requires a very small storage size").
    pub index: Vec<TileRowMeta>,
    /// Matrix-index extension: per-tile-row tile-column ids, ascending,
    /// flat (`col_offsets[tr]..col_offsets[tr + 1]` indexes `col_ids`).
    /// One `u32` per *tile* — the same order of magnitude as the §3.3.1
    /// index itself.  The streamed subsystem's read-ahead scheduler uses
    /// it to know the tile structure (which input intervals a tile row
    /// touches, which hop-1 intervals a transposed walk will demand)
    /// without reading a SEM image from SAFS.
    pub col_offsets: Vec<usize>,
    pub col_ids: Vec<u32>,
    pub storage: Storage,
    /// Tile encoding flag the image was built with (the Fig. 6 ablation
    /// axis); delta patches must re-encode with the same flag.
    pub coo_hybrid: bool,
    /// Pending edge mutations over the base image — see
    /// [`crate::sparse::delta`] for the merge/compaction contract.
    /// `None` until the first [`apply_delta`](SparseMatrix::apply_delta).
    pub overlay: Option<DeltaOverlay>,
}

impl SparseMatrix {
    pub fn num_tile_rows(&self) -> usize {
        self.index.len()
    }

    /// Ascending tile-column ids of tile row `tr` (from the in-RAM matrix
    /// index extension — no image I/O).
    pub fn tile_cols(&self, tr: usize) -> &[u32] {
        &self.col_ids[self.col_offsets[tr]..self.col_offsets[tr + 1]]
    }

    /// Total bytes of the tile image.
    pub fn storage_bytes(&self) -> u64 {
        self.index.iter().map(|m| m.len as u64).sum()
    }

    pub fn is_external(&self) -> bool {
        matches!(self.storage, Storage::Safs { .. })
    }

    /// Rows covered by tile row `i`: `[start, end)`.
    pub fn tile_row_range(&self, i: usize) -> (u64, u64) {
        let start = (i * self.tile_dim) as u64;
        (start, (start + self.tile_dim as u64).min(self.n_rows))
    }

    /// Borrow the bytes of tile row `i` if they are in memory: a delta
    /// patch when the overlay holds the row, the base image otherwise.
    pub fn tile_row_mem(&self, i: usize) -> Option<&[u8]> {
        if let Some(bytes) = self.overlay.as_ref().and_then(|ov| ov.rows.get(&i)) {
            return Some(bytes);
        }
        match &self.storage {
            Storage::Mem(buf) => {
                let m = self.index[i];
                Some(&buf[m.offset as usize..m.offset as usize + m.len as usize])
            }
            Storage::Safs { .. } => None,
        }
    }

    /// The effective image bytes of tile row `tr` given its base-image
    /// bytes `base`: the overlay's patched row when one exists, `base`
    /// otherwise.  The SEM walks read the base byte ranges (walk
    /// geometry and byte accounting are overlay-invariant) and call this
    /// at compute time — the "base sweep + delta sweep" fusion point.
    pub fn effective_row_image<'a>(&'a self, tr: usize, base: &'a [u8]) -> &'a [u8] {
        match self.overlay.as_ref().and_then(|ov| ov.rows.get(&tr)) {
            Some(patched) => patched,
            None => base,
        }
    }

    /// Synchronously read the effective bytes of tile row `i` into `buf`
    /// (resized as needed): the overlay's patched row when one exists,
    /// the base image otherwise.  Works for both storage kinds; the SEM
    /// engine uses async reads via the SAFS handle instead.
    pub fn read_tile_row(&self, i: usize, buf: &mut Vec<u8>) {
        if let Some(bytes) = self.overlay.as_ref().and_then(|ov| ov.rows.get(&i)) {
            buf.clear();
            buf.extend_from_slice(bytes);
            return;
        }
        let m = self.index[i];
        match &self.storage {
            Storage::Mem(image) => {
                buf.clear();
                buf.extend_from_slice(
                    &image[m.offset as usize..m.offset as usize + m.len as usize],
                );
            }
            Storage::Safs { fs, file } => {
                buf.resize(m.len as usize, 0);
                let data = fs
                    .read_async(file.clone(), m.offset, std::mem::take(buf))
                    .wait();
                *buf = data;
            }
        }
    }

    /// SAFS handle for SEM streaming (None when in memory).
    pub fn safs_handle(&self) -> Option<(&Arc<Safs>, &FileHandle)> {
        match &self.storage {
            Storage::Safs { fs, file } => Some((fs, file)),
            Storage::Mem(_) => None,
        }
    }

    /// Sum of all values (debug/metrics; 1.0 per entry when unweighted).
    pub fn value_sum(&self) -> f64 {
        let mut total = 0.0f64;
        let mut buf = Vec::new();
        for i in 0..self.num_tile_rows() {
            self.read_tile_row(i, &mut buf);
            for (_, view) in TileRowView::new(&buf, self.value_elem) {
                view.for_each(|_, _, v| total += v);
            }
        }
        total
    }

    /// Collect all nonzeros as global (row, col, value) triples — test
    /// helper, O(nnz) memory.
    pub fn to_triples(&self) -> Vec<(u64, u64, f64)> {
        let mut out = Vec::with_capacity(self.nnz as usize);
        let mut buf = Vec::new();
        for i in 0..self.num_tile_rows() {
            let row_base = (i * self.tile_dim) as u64;
            self.read_tile_row(i, &mut buf);
            for (tile_col, view) in TileRowView::new(&buf, self.value_elem) {
                let col_base = tile_col as u64 * self.tile_dim as u64;
                view.for_each(|r, c, v| out.push((row_base + r as u64, col_base + c as u64, v)));
            }
        }
        out.sort_by(|a, b| (a.0, a.1).partial_cmp(&(b.0, b.1)).unwrap());
        out
    }
}

/// Iterator over the tiles of one tile-row byte image, yielding
/// `(tile_col, TileView)`.
pub struct TileRowView<'a> {
    bytes: &'a [u8],
    value_elem: usize,
    remaining: usize,
    pos: usize,
}

impl<'a> TileRowView<'a> {
    /// `value_elem` is the image's stored value width
    /// ([`SparseMatrix::value_elem`]): 0, 4, or 8.
    pub fn new(bytes: &'a [u8], value_elem: usize) -> TileRowView<'a> {
        let ntiles = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        TileRowView { bytes, value_elem, remaining: ntiles, pos: 8 }
    }
}

impl<'a> Iterator for TileRowView<'a> {
    type Item = (u32, TileView<'a>);

    fn next(&mut self) -> Option<Self::Item> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let tile_col =
            u32::from_le_bytes(self.bytes[self.pos..self.pos + 4].try_into().unwrap());
        let len =
            u32::from_le_bytes(self.bytes[self.pos + 4..self.pos + 8].try_into().unwrap())
                as usize;
        let payload = &self.bytes[self.pos + 8..self.pos + 8 + len];
        self.pos += 8 + len;
        Some((tile_col, TileView::parse(payload, self.value_elem)))
    }
}

/// Assemble a tile-row byte image from encoded tiles.
pub fn assemble_tile_row(tiles: &[(u32, Vec<u8>)]) -> Vec<u8> {
    let total: usize = tiles.iter().map(|(_, p)| 8 + p.len()).sum();
    let mut out = Vec::with_capacity(8 + total);
    out.extend_from_slice(&(tiles.len() as u32).to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    for (col, payload) in tiles {
        debug_assert_eq!(payload.len() % 4, 0);
        out.extend_from_slice(&col.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(payload);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::tile::encode_tile;

    #[test]
    fn tile_row_view_iterates_tiles() {
        let t0 = encode_tile(&[(0, 1), (0, 2)], None, 16);
        let t1 = encode_tile(&[(3, 3)], None, 16);
        let row = assemble_tile_row(&[(0, t0), (5, t1)]);
        let tiles: Vec<(u32, usize)> =
            TileRowView::new(&row, 0).map(|(c, v)| (c, v.nnz())).collect();
        assert_eq!(tiles, vec![(0, 2), (5, 1)]);
    }

    #[test]
    fn empty_tile_row() {
        let row = assemble_tile_row(&[]);
        assert_eq!(TileRowView::new(&row, 0).count(), 0);
    }
}
