//! Reproduction runners for every table and figure of the paper's
//! evaluation (§4).  Each function runs the workload and returns a
//! [`Table`] whose rows mirror what the paper plots; the bench binaries
//! under `rust/benches/` are thin wrappers around these.

use super::report::{ratio, secs, Table};
use super::scenarios::{rmat_churn, BenchCfg};
use crate::dense::{
    mv_times_mat_add_mv, mv_trans_mv, tas::mv_random, DenseCtx, NativeKernels, SmallMat,
    TasMatrix,
};
use crate::eigen::{
    ortho_normalize, solve, CsrMode, CsrOperator, EigenConfig, Operator, SpmmOperator, Which,
};
use crate::graph::Dataset;
use crate::safs::{IoStats, Safs, SafsConfig, StoragePrecision, WaitMode};
use crate::service::{GraphSession, JobReport, JobSpec, SolverPool};
use std::collections::BTreeMap;
use crate::sparse::{build_matrix_opts, BuildTarget, CooMatrix, CsrMatrix};
use crate::spmm::{spmm, spmm_csr, spmm_trilinos_like, DenseBlock, SpmmOpts};
use crate::util::humansize::{fmt_bytes, fmt_throughput};
use crate::util::timer::{bench_mean, time_it};
use std::sync::Arc;

// ---------------------------------------------------------------- Table 2

/// Table 2: the graph datasets (paper scale vs our generated scale).
pub fn table2(cfg: &BenchCfg) -> Table {
    let mut t = Table::new(
        "Table 2: graph datasets (scaled reproduction)",
        &[
            "graph", "paper |V|", "paper |E|", "directed", "weighted", "our |V|", "our |E|",
            "image", "CSR-8B",
        ],
    );
    for ds in Dataset::all() {
        let (pv, pe) = ds.paper_scale();
        let coo = cfg.gen(ds);
        let m = cfg.build_im(&coo);
        let csr8 = 8 * coo.nnz() as u64 + 8 * coo.n_rows;
        t.row(vec![
            ds.name().into(),
            format!("{pv}"),
            format!("{pe}"),
            format!("{}", ds.directed()),
            format!("{}", ds.weighted()),
            format!("{}", coo.n_rows),
            format!("{}", coo.nnz()),
            fmt_bytes(m.storage_bytes()),
            fmt_bytes(csr8),
        ]);
    }
    t.note(format!(
        "scale = {:.2e} of Table 2; SCSR+COO image vs 8-byte-index CSR model",
        cfg.scale
    ));
    t
}

// ------------------------------------------------------------------ Fig 6

/// Figure 6: effectiveness of the SpMM memory optimizations, applied
/// cumulatively, per graph and dense-matrix width.
pub fn fig6(cfg: &BenchCfg, datasets: &[Dataset], cols: &[usize]) -> Table {
    let mut t = Table::new(
        "Figure 6: SpMM optimization ablation (in-memory, cumulative)",
        &["graph", "b", "stage", "runtime", "speedup vs CSR"],
    );
    for &ds in datasets {
        let coo = cfg.gen(ds);
        let csr = CsrMatrix::from_coo(&coo);
        let tiled_scsr = build_matrix_opts(&coo, cfg.tile_dim, BuildTarget::Mem, false);
        let tiled_hybrid = build_matrix_opts(&coo, cfg.tile_dim, BuildTarget::Mem, true);
        let n = coo.n_rows as usize;
        for &b in cols {
            let mut base_time = None;
            for (label, opts) in SpmmOpts::stages() {
                let input = DenseBlock::from_fn(n, b, cfg.tile_dim, opts.numa, |r, c| {
                    ((r * 13 + c * 7) % 31) as f64 - 15.0
                });
                let mut output = DenseBlock::new(n, b, cfg.tile_dim, opts.numa);
                let secs_mean = if !opts.cache_block {
                    bench_mean(1, 3, || {
                        spmm_csr(&csr, &input, &mut output, cfg.threads, opts.vectorize)
                    })
                } else {
                    let m = if opts.scsr_coo { &tiled_hybrid } else { &tiled_scsr };
                    bench_mean(1, 3, || {
                        spmm(m, &input, &mut output, &opts, cfg.threads);
                    })
                };
                let base = *base_time.get_or_insert(secs_mean);
                t.row(vec![
                    ds.name().into(),
                    format!("{b}"),
                    label.into(),
                    secs(secs_mean),
                    ratio(base / secs_mean),
                ]);
            }
        }
    }
    t.note(
        "paper shape: all opts together = 2-4x over CSR; cache blocking strongest at small b",
    );
    t
}

// ------------------------------------------------------------------ Fig 7

/// Figure 7: SpMM runtime of FE-IM, FE-SEM, MKL-like and Trilinos-like on
/// the Friendster graph across dense-matrix widths.
pub fn fig7(cfg: &BenchCfg, cols: &[usize]) -> Table {
    let mut t = Table::new(
        "Figure 7: SpMM runtime on Friendster (FE-IM / FE-SEM / MKL / Trilinos)",
        &["b", "FE-IM", "FE-SEM", "MKL-like", "Trilinos-like", "SEM/IM"],
    );
    let coo = cfg.gen(Dataset::Friendster);
    let csr = CsrMatrix::from_coo(&coo);
    let im = cfg.build_im(&coo);
    let fs = cfg.timed_safs();
    let sem = cfg.build_sem(&coo, &fs, "fig7");
    let n = coo.n_rows as usize;
    let opts = SpmmOpts::default();
    for &b in cols {
        let input =
            DenseBlock::from_fn(n, b, cfg.tile_dim, true, |r, c| ((r + c) % 17) as f64 - 8.0);
        let mut output = DenseBlock::new(n, b, cfg.tile_dim, true);
        let t_im = bench_mean(1, 3, || {
            spmm(&im, &input, &mut output, &opts, cfg.threads);
        });
        let t_sem = bench_mean(1, 3, || {
            spmm(&sem, &input, &mut output, &opts, cfg.threads);
        });
        let t_mkl = bench_mean(1, 3, || {
            spmm_csr(&csr, &input, &mut output, cfg.threads, true)
        });
        let t_tri = bench_mean(1, 3, || {
            spmm_trilinos_like(&csr, &input, &mut output, cfg.threads)
        });
        t.row(vec![
            format!("{b}"),
            secs(t_im),
            secs(t_sem),
            secs(t_mkl),
            secs(t_tri),
            ratio(t_im / t_sem),
        ]);
    }
    t.note(
        "paper shape: SEM ≈ 60% of IM at b=1, gap narrows with b; FE beats MKL 2-3x and Trilinos",
    );
    t
}

// ------------------------------------------------------------------ Fig 8

/// Figure 8: Trilinos and FE-SEM sparse multiply relative to FE-IM, per
/// graph, for SpMV (b=1) and SpMM (b=4).
pub fn fig8(cfg: &BenchCfg) -> Table {
    let mut t = Table::new(
        "Figure 8: relative sparse-multiply performance (FE-IM = 1.0)",
        &["graph", "op", "Trilinos/FE-IM", "FE-SEM/FE-IM"],
    );
    for ds in [Dataset::Twitter, Dataset::Friendster, Dataset::Knn] {
        let coo = cfg.gen(ds);
        let csr = CsrMatrix::from_coo(&coo);
        let im = cfg.build_im(&coo);
        let fs = cfg.timed_safs();
        let sem = cfg.build_sem(&coo, &fs, "fig8");
        let n = coo.n_rows as usize;
        let opts = SpmmOpts::default();
        for (op, b) in [("SpMV", 1usize), ("SpMM b=4", 4)] {
            let input =
                DenseBlock::from_fn(n, b, cfg.tile_dim, true, |r, c| ((r * 3 + c) % 11) as f64);
            let mut output = DenseBlock::new(n, b, cfg.tile_dim, true);
            let t_im = bench_mean(1, 3, || {
                spmm(&im, &input, &mut output, &opts, cfg.threads);
            });
            let t_sem = bench_mean(1, 3, || {
                spmm(&sem, &input, &mut output, &opts, cfg.threads);
            });
            let t_tri = bench_mean(1, 3, || {
                spmm_trilinos_like(&csr, &input, &mut output, cfg.threads)
            });
            t.row(vec![
                ds.name().into(),
                op.into(),
                ratio(t_im / t_tri),
                ratio(t_im / t_sem),
            ]);
        }
    }
    t.note("paper shape: FE-IM ≥ 1.36x Trilinos even for SpMV; FE-SEM ≥ 0.6 of FE-IM");
    t
}

// ------------------------------------------------------------------ Fig 9

/// One I/O-ablation stage for Figure 9.
fn fig9_config(cfg: &BenchCfg, stage: usize) -> SafsConfig {
    let mut c = cfg.safs_config();
    // Baseline: same stripe order for all files, no buffer pool, one I/O
    // thread per worker, blocking waits, small kernel request size.
    c.diff_stripe_order = stage >= 1;
    c.use_buffer_pool = stage >= 2;
    c.io_threads = if stage >= 3 { 1 } else { cfg.threads };
    c.wait_mode = if stage >= 4 { WaitMode::Polling } else { WaitMode::Blocking };
    c.max_io_size = if stage >= 5 { c.stripe_block } else { 32 << 10 };
    c
}

pub const FIG9_STAGES: [&str; 6] =
    ["base", "+diff strip", "+buf pool", "+1 IO thread", "+polling", "+max block"];

/// Figure 9: I/O optimizations on external-memory dense matrix multiply
/// (op2 / MvTransMv form), applied cumulatively.
pub fn fig9(cfg: &BenchCfg, n: usize, m: usize, b: usize) -> Table {
    let mut t = Table::new(
        "Figure 9: I/O optimization ablation on EM dense MM (MvTransMv)",
        &["stage", "runtime", "speedup vs base"],
    );
    let mut base_time = None;
    for (stage, label) in FIG9_STAGES.iter().enumerate() {
        let fs = Safs::new(fig9_config(cfg, stage));
        // cache_slots = 0: every operand is streamed from the array.
        let ctx = DenseCtx::with(
            fs,
            true,
            cfg.interval_rows,
            cfg.threads,
            8,
            0,
            Arc::new(NativeKernels),
        );
        let mats: Vec<TasMatrix> = (0..m / b)
            .map(|i| {
                let x = TasMatrix::zeros(&ctx, n, b);
                mv_random(&x, 100 + i as u64);
                x
            })
            .collect();
        let refs: Vec<&TasMatrix> = mats.iter().collect();
        let y = TasMatrix::zeros(&ctx, n, b);
        mv_random(&y, 7);
        let t_run = bench_mean(1, 2, || {
            let _ = mv_trans_mv(1.0, &refs, &y);
        });
        let base = *base_time.get_or_insert(t_run);
        t.row(vec![(*label).into(), secs(t_run), ratio(base / t_run)]);
    }
    t.note(format!(
        "n={n}, m={m}, b={b}; paper shape: buf pool + fewer I/O threads dominate; all together ≈ 4x"
    ));
    t
}

// ------------------------------------------------------------- Fig 9b

/// Measure one full CGS2 + Cholesky-QR chain (§3.4's dominant
/// reorthogonalization workload) over an EM subspace of `m/b` streamed
/// basis blocks, in eager and fused mode.  Returns
/// `(label, runtime_secs, io_delta)` rows — the raw data behind
/// [`fig9_fusion`], also used by the I/O-accounting regression tests.
pub fn fig9_fusion_data(
    cfg: &BenchCfg,
    n: usize,
    m: usize,
    b: usize,
) -> Vec<(&'static str, f64, IoStats)> {
    assert_eq!(m % b, 0, "m must be a multiple of b");
    let mut rows = Vec::new();
    for (label, fused) in [("eager (op-by-op)", false), ("fused (lazy eval)", true)] {
        let fs = Safs::new(cfg.safs_config());
        // cache_slots = 1: only the newest block is resident, the basis
        // streams from the array — the paper's §3.4.4 configuration.
        let ctx = DenseCtx::with(
            fs.clone(),
            true,
            cfg.interval_rows,
            cfg.threads,
            8,
            1,
            Arc::new(NativeKernels),
        );
        // The eager row is the explicit ablation reference, never an
        // inherited context default (fused + streamed is the default).
        ctx.set_eager(!fused);
        let mats: Vec<TasMatrix> = (0..m / b)
            .map(|i| {
                let x = TasMatrix::zeros(&ctx, n, b);
                mv_random(&x, 500 + i as u64);
                x
            })
            .collect();
        let refs: Vec<&TasMatrix> = mats.iter().collect();
        let x = TasMatrix::zeros(&ctx, n, b);
        mv_random(&x, 77);
        let before = fs.stats();
        let (_, el) = time_it(|| {
            let _ = ortho_normalize(&refs, &x, 1234);
        });
        rows.push((label, el, fs.stats().delta_since(&before)));
    }
    rows
}

// ------------------------------------------------------------- Fig 9c

/// Measure one operator apply (`W = A·X`) over an EM subspace in the
/// eager ConvLayout→SpMM→ConvLayout path vs the streamed interval-
/// granular boundary.  Write-through context (`cache_slots = 0`) so the
/// eager path's intermediate round trips are visible as SAFS bytes.
/// Returns `(label, runtime_secs, io_delta, peak_dense_bytes)` rows —
/// the raw data behind [`fig9_stream`], also pinned by the
/// I/O-accounting regression tests.
pub fn fig9_stream_data(
    cfg: &BenchCfg,
    n_scale: f64,
    b: usize,
) -> Vec<(&'static str, f64, IoStats, u64)> {
    let mut scaled = cfg.clone();
    scaled.scale *= n_scale;
    let mut coo = scaled.gen(Dataset::Friendster);
    if Dataset::Friendster.directed() {
        coo.symmetrize();
    }
    let mut rows = Vec::new();
    for (label, streamed) in [("eager (3x full-height)", false), ("streamed (intervals)", true)]
    {
        let fs = Safs::new(scaled.safs_config());
        // cache_slots = 0: the dense boundary's traffic is fully visible.
        let ctx = DenseCtx::with(
            fs.clone(),
            true,
            scaled.interval_rows,
            scaled.threads,
            8,
            0,
            Arc::new(NativeKernels),
        );
        // Explicit path selection for both rows (the apply below is also
        // called explicitly, but ablations must not lean on defaults).
        ctx.set_eager(!streamed);
        let op = SpmmOperator::new(scaled.build_im(&coo), SpmmOpts::default(), scaled.threads);
        let n = coo.n_rows as usize;
        let x = TasMatrix::zeros(&ctx, n, b);
        mv_random(&x, 4242);
        let before = fs.stats();
        ctx.mem.begin_window();
        let (_, el) = time_it(|| {
            let _w = if streamed { op.apply_streamed(&ctx, &x) } else { op.apply(&ctx, &x) };
        });
        rows.push((label, el, fs.stats().delta_since(&before), ctx.mem.window_peak()));
    }
    rows
}

/// Figure 9c (beyond the paper): the streamed operator boundary ablation
/// — full-height eager ConvLayout→SpMM→ConvLayout vs the §3.4
/// interval-granular streamed apply, reporting both SAFS bytes and the
/// peak resident dense working set.
pub fn fig9_stream(cfg: &BenchCfg, n_scale: f64, b: usize) -> Table {
    let mut t = Table::new(
        "Figure 9c: streamed SpMM operator boundary (EM subspace, write-through)",
        &["path", "runtime", "read", "written", "total", "peak dense", "bytes vs eager"],
    );
    let rows = fig9_stream_data(cfg, n_scale, b);
    let base = rows[0].2.total_bytes().max(1);
    for (label, el, io, peak) in &rows {
        t.row(vec![
            (*label).into(),
            secs(*el),
            fmt_bytes(io.bytes_read),
            fmt_bytes(io.bytes_written),
            fmt_bytes(io.total_bytes()),
            fmt_bytes(*peak),
            ratio(io.total_bytes() as f64 / base as f64),
        ]);
    }
    t.note(
        "eager materializes 3 full-height dense matrices per apply; streamed gathers input \
         intervals on demand and hands finished output intervals straight to the TAS layer",
    );
    t
}

// ------------------------------------------------------------- Fig 9d

/// Measure one SVD-path operator apply (`W = Aᵀ(A·X)`) over an EM
/// subspace in the eager four-full-height path vs the streamed two-hop
/// boundary (chained producers through the bounded staging ring).
/// Write-through context (`cache_slots = 0`) so every dense byte is
/// visible.  Returns `(label, runtime_secs, io_delta, peak_dense_bytes,
/// stage_peak_bytes)` rows — the raw data behind [`fig9_gram`], also
/// pinned by the I/O-accounting regression tests.
pub fn fig9_gram_data(
    cfg: &BenchCfg,
    n_scale: f64,
    b: usize,
) -> Vec<(&'static str, f64, IoStats, u64, u64)> {
    let mut scaled = cfg.clone();
    scaled.scale *= n_scale;
    let coo = scaled.gen(Dataset::Page); // directed: the SVD workload
    let at_coo = coo.transpose();
    let mut rows = Vec::new();
    for (label, streamed) in
        [("eager (4x full-height)", false), ("streamed two-hop (staging ring)", true)]
    {
        let fs = Safs::new(scaled.safs_config());
        let ctx = DenseCtx::with(
            fs.clone(),
            true,
            scaled.interval_rows,
            scaled.threads,
            8,
            0,
            Arc::new(NativeKernels),
        );
        ctx.set_eager(!streamed);
        let op = crate::eigen::GramOperator::new(
            scaled.build_im(&coo),
            scaled.build_im(&at_coo),
            SpmmOpts::default(),
            scaled.threads,
        );
        let n = coo.n_cols as usize;
        let x = TasMatrix::zeros(&ctx, n, b);
        mv_random(&x, 2424);
        let before = fs.stats();
        ctx.mem.begin_window();
        let (_, el) = time_it(|| {
            let _w = if streamed { op.apply_streamed(&ctx, &x) } else { op.apply(&ctx, &x) };
        });
        rows.push((
            label,
            el,
            fs.stats().delta_since(&before),
            ctx.mem.window_peak(),
            ctx.io_phases.dense_peak("spmm.stage"),
        ));
    }
    rows
}

/// Figure 9d (beyond the paper): the streamed two-hop Gram ablation for
/// the SVD path — eager `Aᵀ(A·X)` with four full-height dense matrices
/// vs the chained-producer apply whose `A·X` intermediate lives in a
/// `group_size`-bounded staging ring.
pub fn fig9_gram(cfg: &BenchCfg, n_scale: f64, b: usize) -> Table {
    let mut t = Table::new(
        "Figure 9d: streamed two-hop Gram operator (SVD path, write-through EM)",
        &[
            "path", "runtime", "read", "written", "total", "peak dense", "stage peak",
            "bytes vs eager",
        ],
    );
    let rows = fig9_gram_data(cfg, n_scale, b);
    let base = rows[0].2.total_bytes().max(1);
    for (label, el, io, peak, stage) in &rows {
        t.row(vec![
            (*label).into(),
            secs(*el),
            fmt_bytes(io.bytes_read),
            fmt_bytes(io.bytes_written),
            fmt_bytes(io.total_bytes()),
            fmt_bytes(*peak),
            if *stage > 0 { fmt_bytes(*stage) } else { "-".into() },
            ratio(io.total_bytes() as f64 / base as f64),
        ]);
    }
    t.note(
        "eager materializes 4 full-height dense matrices per Aᵀ(A·X); the two-hop chain stages \
         at most group_size finished A·X intervals (plus one in use per worker) and recomputes \
         evicted intervals from the resident input gather",
    );
    t
}

// ------------------------------------------------------------- Fig 9e

/// Measure one streamed SEM operator apply (`W = A·X`, matrix image on
/// SSDs, subspace on SSDs) per read-ahead depth.  Depth 0 is the
/// synchronous baseline (every tile-row-image read issued and awaited
/// back-to-back); deeper schedules keep more interval reads in flight
/// per worker.  Bytes are identical by construction — the row that
/// moves is `io_wait`, the blocked-on-ticket time the scheduler hides
/// behind multiplication.  Returns `(depth, runtime_secs, io_delta)`
/// rows — the raw data behind [`fig9_readahead`], also pinned by the
/// I/O-accounting regression tests.
pub fn fig9_readahead_data(
    cfg: &BenchCfg,
    n_scale: f64,
    b: usize,
    depths: &[usize],
) -> Vec<(usize, f64, IoStats)> {
    let mut scaled = cfg.clone();
    scaled.scale *= n_scale;
    let mut coo = scaled.gen(Dataset::Friendster);
    if Dataset::Friendster.directed() {
        coo.symmetrize();
    }
    let mut rows = Vec::new();
    for &depth in depths {
        let mut per_depth = scaled.clone();
        per_depth.read_ahead = depth;
        let fs = Safs::new(per_depth.safs_config());
        // cache_slots = 0: the subspace streams, so the walk has real
        // SEM reads on both the image and the dense side to overlap.
        let ctx = DenseCtx::with(
            fs.clone(),
            true,
            per_depth.interval_rows,
            per_depth.threads,
            8,
            0,
            Arc::new(NativeKernels),
        );
        let op = SpmmOperator::new(
            per_depth.build_sem(&coo, &fs, "fig9e"),
            SpmmOpts::default(),
            per_depth.threads,
        );
        let n = coo.n_rows as usize;
        let x = TasMatrix::zeros(&ctx, n, b);
        mv_random(&x, 4242);
        let before = fs.stats();
        let (_, el) = time_it(|| {
            let _w = op.apply_streamed(&ctx, &x);
        });
        rows.push((depth, el, fs.stats().delta_since(&before)));
    }
    rows
}

/// Figure 9e (beyond the paper): read-ahead ablation on the streamed
/// SEM operator apply — same bytes at every depth, shrinking `io_wait`
/// as the scheduler overlaps image transfers with multiplication.
pub fn fig9_readahead(cfg: &BenchCfg, n_scale: f64, b: usize) -> Table {
    let mut t = Table::new(
        "Figure 9e: read-ahead ablation on the streamed SEM apply",
        &["depth", "runtime", "read", "io wait", "wait vs depth 0"],
    );
    let rows = fig9_readahead_data(cfg, n_scale, b, &[0, 2, 8]);
    let base_wait = rows[0].2.wait_secs().max(1e-12);
    for (depth, el, io) in &rows {
        t.row(vec![
            format!("{depth}"),
            secs(*el),
            fmt_bytes(io.bytes_read),
            format!("{:.3}s", io.wait_secs()),
            ratio(io.wait_secs() / base_wait),
        ]);
    }
    t.note(
        "scheduling moves when bytes are read, never what is computed: identical reads per row, \
         lower blocked-wait as depth grows (the §3.2 I/O/compute overlap, restored on the \
         streamed default path)",
    );
    t
}

// ------------------------------------------------------------- Fig 9f

/// Measure repeated streamed SEM operator applies (`W = A·X`, image and
/// subspace on SSDs) per cross-apply image-cache budget
/// ([`crate::safs::SafsConfig::image_cache_bytes`]).  Budget 0 is the
/// cache-off baseline; the other rows grant ¼-image and one-image of
/// explicit RAM headroom.  Returns
/// `(label, budget, cold_io, warm_io_total, cache_peak)` rows — cold is
/// the first apply's delta, warm the accumulated deltas of the
/// remaining `applies − 1` — the raw data behind [`fig9_imgcache`],
/// also pinned by the I/O-accounting regression tests.
pub fn fig9_imgcache_data(
    cfg: &BenchCfg,
    n_scale: f64,
    b: usize,
    applies: usize,
) -> Vec<(&'static str, u64, IoStats, IoStats, u64)> {
    assert!(applies >= 2, "need at least one warm apply");
    let mut scaled = cfg.clone();
    scaled.scale *= n_scale;
    let mut coo = scaled.gen(Dataset::Friendster);
    if Dataset::Friendster.directed() {
        coo.symmetrize();
    }
    // The image byte total is a function of the layout alone, so a
    // throwaway in-memory build sizes the budgets.
    let image_bytes = scaled.build_im(&coo).storage_bytes();
    let mut rows = Vec::new();
    for (label, budget) in [
        ("off", 0u64),
        ("1/4 image", image_bytes / 4),
        ("full image", image_bytes),
    ] {
        let mut per_budget = scaled.clone();
        per_budget.image_cache = budget;
        let fs = Safs::new(per_budget.safs_config());
        // cache_slots = 0: the subspace is write-through, so the image
        // share of every apply is cleanly visible next to it.
        let ctx = DenseCtx::with(
            fs.clone(),
            true,
            per_budget.interval_rows,
            per_budget.threads,
            8,
            0,
            Arc::new(NativeKernels),
        );
        let op = SpmmOperator::new(
            per_budget.build_sem(&coo, &fs, "fig9f"),
            SpmmOpts::default(),
            per_budget.threads,
        );
        let n = coo.n_rows as usize;
        let x = TasMatrix::zeros(&ctx, n, b);
        mv_random(&x, 4242);
        let mut cold = IoStats::default();
        let mut warm = IoStats::default();
        for i in 0..applies {
            let before = fs.stats();
            let _w = op.apply_streamed(&ctx, &x);
            let delta = fs.stats().delta_since(&before);
            if i == 0 {
                cold = delta;
            } else {
                warm.accumulate(&delta);
            }
        }
        rows.push((label, budget, cold, warm, fs.image_cache().mem().peak()));
    }
    rows
}

/// Figure 9f (beyond the paper): the cross-apply SEM image residency
/// ablation — repeated streamed applies under image-cache budgets
/// {0, ¼ image, one image}, reporting the cold apply, the mean warm
/// apply, the residency hit share and the cache's peak footprint.
/// Steady-state image traffic moves from O(applies × image) toward
/// O(image) as the budget approaches one image.
pub fn fig9_imgcache(cfg: &BenchCfg, n_scale: f64, b: usize) -> Table {
    const APPLIES: usize = 3;
    let mut t = Table::new(
        "Figure 9f: cross-apply SEM image residency (3 streamed applies)",
        &[
            "budget", "bytes", "cold read", "warm read/apply", "hit share", "cache peak",
            "warm vs off",
        ],
    );
    let rows = fig9_imgcache_data(cfg, n_scale, b, APPLIES);
    let w = (APPLIES - 1) as u64;
    let base_warm = (rows[0].3.bytes_read / w).max(1);
    for (label, budget, cold, warm, peak) in &rows {
        let warm_read = warm.bytes_read / w;
        let demanded = warm.cache_hit_bytes + warm.cache_miss_bytes;
        let share = if demanded > 0 {
            format!("{:.0}%", 100.0 * warm.cache_hit_bytes as f64 / demanded as f64)
        } else {
            "-".into()
        };
        t.row(vec![
            (*label).into(),
            fmt_bytes(*budget),
            fmt_bytes(cold.bytes_read),
            fmt_bytes(warm_read),
            share,
            fmt_bytes(*peak),
            ratio(warm_read as f64 / base_warm as f64),
        ]);
    }
    t.note(
        "caching moves when/whether image bytes are read, never what is computed: results are \
         bitwise identical at every budget; a full-image budget makes warm applies image-free \
         (reads shrink to the subspace gather) and the cache peak never exceeds the budget",
    );
    t
}

// ------------------------------------------------------------- Fig 9g

/// Measure one full SEM eigensolve (image and subspace on SSDs) per
/// storage precision, at a **pinned** iteration count (unreachable
/// tolerance + fixed restart budget) so the byte columns compare like
/// for like.  Returns `(precision, image_bytes, io_delta,
/// worst_residual, operator_applies)` rows — the raw data behind
/// [`fig9_precision`], also pinned by the I/O-accounting and precision
/// regression tests.
pub fn fig9_precision_data(
    cfg: &BenchCfg,
    n_scale: f64,
    nev: usize,
) -> Vec<(&'static str, u64, IoStats, f64, u64)> {
    let mut scaled = cfg.clone();
    scaled.scale *= n_scale;
    let mut coo = scaled.gen(Dataset::Friendster);
    if Dataset::Friendster.directed() {
        coo.symmetrize();
    }
    let defaults = EigenConfig::paper_defaults(nev);
    let mut rows = Vec::new();
    for prec in [StoragePrecision::F64, StoragePrecision::F32] {
        let mut per_prec = scaled.clone();
        per_prec.storage_precision = prec;
        let fs = Safs::new(per_prec.safs_config());
        let ctx = per_prec.dense_ctx_native(fs.clone(), true);
        let matrix = per_prec.build_sem(&coo, &fs, "fig9g");
        let image_bytes = matrix.storage_bytes();
        let op = SpmmOperator::new(matrix, SpmmOpts::default(), per_prec.threads);
        let ecfg = EigenConfig {
            nev,
            block_size: defaults.block_size,
            num_blocks: defaults.num_blocks,
            // Unreachable tolerance + pinned restart budget: both
            // precisions run exactly the same iterations, so the byte
            // columns differ only through the storage width.
            tol: 1e-300,
            max_restarts: 3,
            which: Which::LargestMagnitude,
            seed: per_prec.seed,
            compute_eigenvectors: false,
            refine_steps: 0,
            warm_start: None,
        };
        let before = fs.stats();
        let res = solve(&op, &ctx, &ecfg);
        let io = fs.stats().delta_since(&before);
        let worst = res.residuals.iter().cloned().fold(0.0f64, f64::max);
        rows.push((prec.name(), image_bytes, io, worst, res.operator_applies));
    }
    rows
}

/// Figure 9g (beyond the paper): the storage-precision ablation — the
/// same pinned-iteration SEM eigensolve under f64 and f32 storage,
/// reporting the serialized image size, the SAFS bytes moved and the
/// worst residual `‖A·v − θ·v‖`.  Narrowing what is *stored* halves the
/// subspace traffic; every accumulation still runs in f64, so the
/// residual column moves only within the input-rounding bound.
pub fn fig9_precision(cfg: &BenchCfg, n_scale: f64, nev: usize) -> Table {
    let mut t = Table::new(
        "Figure 9g: storage-precision ablation on the SEM eigensolve (pinned iterations)",
        &["precision", "image", "read", "written", "total", "worst residual", "bytes vs f64"],
    );
    let rows = fig9_precision_data(cfg, n_scale, nev);
    let base = rows[0].2.total_bytes().max(1);
    for (label, image, io, worst, _applies) in &rows {
        t.row(vec![
            (*label).into(),
            fmt_bytes(*image),
            fmt_bytes(io.bytes_read),
            fmt_bytes(io.bytes_written),
            fmt_bytes(io.total_bytes()),
            format!("{worst:.2e}"),
            ratio(io.total_bytes() as f64 / base as f64),
        ]);
    }
    t.note(
        "identical iteration counts by construction (unreachable tol, pinned restarts); f32 \
         halves every stored subspace interval while unweighted/f32-native images are \
         byte-identical, so 'bytes vs f64' isolates the subspace saving; arithmetic is f64 \
         under both rows — see tests/precision.rs for the residual-bound differential tier",
    );
    t
}

/// Figure 9b (beyond the paper): the §3.4 lazy-evaluation ablation —
/// eager op-by-op CGS2 vs the fused single-pass-per-round pipeline, on
/// the same EM dense-matrix configuration as Figure 9.
pub fn fig9_fusion(cfg: &BenchCfg, n: usize, m: usize, b: usize) -> Table {
    let mut t = Table::new(
        "Figure 9b: lazy-evaluation fusion on EM CGS2 reorthogonalization",
        &["path", "runtime", "read", "written", "total", "bytes vs eager"],
    );
    let rows = fig9_fusion_data(cfg, n, m, b);
    let base = rows[0].2.total_bytes().max(1);
    for (label, el, io) in &rows {
        t.row(vec![
            (*label).into(),
            secs(*el),
            fmt_bytes(io.bytes_read),
            fmt_bytes(io.bytes_written),
            fmt_bytes(io.total_bytes()),
            ratio(io.total_bytes() as f64 / base as f64),
        ]);
    }
    t.note(format!(
        "n={n}, m={m}, b={b}; fused CGS2 streams the subspace once per round (2 reads total) \
         vs 4 for eager, and the normalization grams ride along in the same walks"
    ));
    t
}

// ----------------------------------------------------------- Fig 10 / 11

/// Single-threaded dense comparators for op1 (stand-ins for MKL/Trilinos
/// in-memory dense GEMM; see DESIGN.md §1).
fn dense_baseline_mkl(x: &[f64], rows: usize, m: usize, bmat: &SmallMat, out: &mut [f64]) {
    use crate::dense::DenseKernels;
    NativeKernels.tsgemm(x, rows, m, bmat, out);
}

fn dense_baseline_trilinos(x: &[f64], rows: usize, m: usize, bmat: &SmallMat, out: &mut [f64]) {
    crate::dense::kernels::reference::tsgemm(x, rows, m, bmat, out);
}

/// Figure 10: op1 (`MvTimesMatAddMv`) runtime across subspace sizes m —
/// FE-IM vs FE-EM vs the in-memory MKL/Trilinos stand-ins.
pub fn fig10(cfg: &BenchCfg, n: usize, b: usize, m_list: &[usize]) -> Table {
    let mut t = Table::new(
        "Figure 10: dense MM op1 runtime (n x m  ·  m x b)",
        &["m", "FE-IM", "FE-EM", "MKL-like", "Trilinos-like", "EM/IM"],
    );
    for &m in m_list {
        let (t_im, t_em, _, _) = fig10_point(cfg, n, b, m);
        // In-memory single-thread baselines over one contiguous buffer.
        let x: Vec<f64> = (0..n * m).map(|i| ((i * 31) % 101) as f64 - 50.0).collect();
        let bmat = SmallMat::from_fn(m, b, |r, c| ((r + 2 * c) % 7) as f64 - 3.0);
        let mut out = vec![0.0; n * b];
        let t_mkl = bench_mean(1, 2, || {
            out.fill(0.0);
            dense_baseline_mkl(&x, n, m, &bmat, &mut out);
        });
        let t_tri = bench_mean(1, 2, || {
            out.fill(0.0);
            dense_baseline_trilinos(&x, n, m, &bmat, &mut out);
        });
        t.row(vec![
            format!("{m}"),
            secs(t_im),
            secs(t_em),
            secs(t_mkl),
            secs(t_tri),
            ratio(t_em / t_im),
        ]);
    }
    t.note(
        "paper shape: FE-EM 3-6x slower than FE-IM (I/O bound); FE-IM close to MKL at larger m",
    );
    t
}

/// Measure one (n, b, m) op1 point in IM and EM mode; returns
/// (im_secs, em_secs, em_io_delta, em_elapsed_secs) — the latter two
/// feed Figure 11's throughput/overlap/residency series.
pub fn fig10_point(cfg: &BenchCfg, n: usize, b: usize, m: usize) -> (f64, f64, IoStats, f64) {
    assert_eq!(m % b, 0, "m must be a multiple of b");
    let bmat = SmallMat::from_fn(m, b, |r, c| ((r + 2 * c) % 7) as f64 - 3.0);
    let run = |em: bool| -> (f64, IoStats) {
        let fs = cfg.timed_safs();
        let ctx = DenseCtx::with(
            fs.clone(),
            em,
            cfg.interval_rows,
            cfg.threads,
            8,
            0,
            Arc::new(NativeKernels),
        );
        let mats: Vec<TasMatrix> = (0..m / b)
            .map(|i| {
                let x = TasMatrix::zeros(&ctx, n, b);
                mv_random(&x, 200 + i as u64);
                x
            })
            .collect();
        let refs: Vec<&TasMatrix> = mats.iter().collect();
        let cc = TasMatrix::zeros(&ctx, n, b);
        let before = fs.stats();
        let (_, el) = time_it(|| {
            mv_times_mat_add_mv(1.0, &refs, &bmat, 0.0, &cc);
        });
        (el, fs.stats().delta_since(&before))
    };
    let (t_im, _) = run(false);
    let (t_em, io) = run(true);
    (t_im, t_em, io, t_em)
}

/// Figure 11: average I/O throughput of EM dense MM across m, with the
/// blocked `io_wait` share showing how much of the traffic the async
/// pipeline failed to hide behind computation, and the image-cache
/// residency share of whatever SEM image demand the workload had
/// ("-" when no image traffic flows, as in this dense-only workload
/// under the default cache-off budget).
pub fn fig11(cfg: &BenchCfg, n: usize, b: usize, m_list: &[usize]) -> Table {
    let mut t = Table::new(
        "Figure 11: average I/O throughput of EM dense MM",
        &[
            "m",
            "bytes moved",
            "throughput",
            "per SSD",
            "of array max",
            "io wait",
            "poll",
            "qd",
            "residency",
            "precision",
        ],
    );
    let max_bps = cfg.safs_config().aggregate_read_bps();
    for &m in m_list {
        let (_, _, io, el) = fig10_point(cfg, n, b, m);
        let bytes = io.total_bytes();
        let bps = bytes as f64 / el;
        let demanded = io.cache_hit_bytes + io.cache_miss_bytes;
        let residency = if demanded > 0 {
            format!("{:.0}%", 100.0 * io.cache_hit_bytes as f64 / demanded as f64)
        } else {
            "-".into()
        };
        t.row(vec![
            format!("{m}"),
            fmt_bytes(bytes),
            fmt_throughput(bytes, el),
            fmt_throughput(bytes / 24, el),
            format!("{:.0}%", 100.0 * bps / max_bps),
            format!("{:.3}s", io.wait_secs()),
            // The busy-spin share of io wait, and the peak per-device
            // submission-queue depth the engine reached — how deep the
            // queued backend actually kept the devices' queues.
            format!("{:.3}s", io.poll_secs()),
            io.peak_queue_depth.to_string(),
            residency,
            // The storage width the subspace bytes above were moved at —
            // f32 halves "bytes moved" at identical arithmetic.
            cfg.storage_precision.name().into(),
        ]);
    }
    t.note("paper shape: throughput approaches the array maximum (10.87 of 12 GB/s) — the SSDs are the bottleneck");
    t
}

// ----------------------------------------------------------------- Fig 12

/// Eigensolver run description for Figure 12 / Table 3.
pub struct EigenRun {
    pub runtime: f64,
    pub converged: bool,
    pub restarts: usize,
    pub applies: u64,
    pub peak_mem: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub eigenvalues: Vec<f64>,
    /// Per-phase SAFS traffic (spmm / ortho / restart) from
    /// [`crate::metrics::PhaseIo`].
    pub phase_io: BTreeMap<String, IoStats>,
    /// Per-phase peak resident dense bytes (the §3.4.3 working set).
    pub phase_dense_peaks: BTreeMap<String, u64>,
}

/// Run the Block KrylovSchur solver in one of the Fig. 12 modes.
pub fn run_eigensolver(
    cfg: &BenchCfg,
    coo: &CooMatrix,
    nev: usize,
    mode: &str, // "fe-im" | "fe-sem" | "fe-sem-fused" | "trilinos"
) -> EigenRun {
    // §4.3 parameter choices.
    let (b, nb) = if nev >= 16 { (4, nev) } else { (1, 2 * nev) };
    let ecfg = EigenConfig {
        nev,
        block_size: b,
        num_blocks: nb,
        tol: 1e-6,
        max_restarts: 500,
        which: Which::LargestMagnitude,
        seed: cfg.seed,
        compute_eigenvectors: false,
        refine_steps: 0,
        warm_start: None,
    };
    let fs = cfg.timed_safs();
    let (op, ctx): (Box<dyn Operator>, Arc<DenseCtx>) = match mode {
        "fe-im" => (
            Box::new(SpmmOperator::new(cfg.build_im(coo), SpmmOpts::default(), cfg.threads)),
            cfg.dense_ctx_native(fs.clone(), false),
        ),
        "fe-sem" | "fe-sem-fused" => (
            Box::new(SpmmOperator::new(
                cfg.build_sem(coo, &fs, "eigen-a"),
                SpmmOpts::default(),
                cfg.threads,
            )),
            cfg.dense_ctx_native(fs.clone(), true),
        ),
        "trilinos" => (
            // Trilinos: in-memory, CSR, SpMV-oriented (block 1 handled by
            // the b=1 ecfg above for small nev).
            Box::new(CsrOperator::new(
                CsrMatrix::from_coo(coo),
                CsrMode::TrilinosLike,
                cfg.threads,
            )),
            cfg.dense_ctx_native(fs.clone(), false),
        ),
        _ => panic!("unknown mode {mode}"),
    };
    // Explicit path per mode: "fe-sem-fused" is the fused + streamed
    // configuration (the DenseCtx default — SpMM output flows
    // interval-by-interval into the ortho walk); every other mode pins
    // the eager reference explicitly so the ablation columns never
    // inherit a context default.
    ctx.set_eager(mode != "fe-sem-fused");
    let before = fs.stats();
    let (res, runtime) = time_it(|| solve(op.as_ref(), &ctx, &ecfg));
    let delta = fs.stats().delta_since(&before);
    EigenRun {
        runtime,
        converged: res.converged,
        restarts: res.restarts,
        applies: res.operator_applies,
        peak_mem: ctx.mem.peak(),
        bytes_read: delta.bytes_read,
        bytes_written: delta.bytes_written,
        eigenvalues: res.eigenvalues,
        phase_io: ctx.io_phases.snapshot(),
        phase_dense_peaks: ctx.io_phases.dense_peaks_snapshot(),
    }
}

/// Figure 12: KrylovSchur eigensolver — Trilinos-like and FE-SEM relative
/// to FE-IM, per graph and eigenvalue count.
pub fn fig12(cfg: &BenchCfg, nevs: &[usize], datasets: &[Dataset]) -> Table {
    let mut t = Table::new(
        "Figure 12: eigensolver performance relative to FE-IM KrylovSchur",
        &[
            "graph", "nev", "FE-IM", "Trilinos", "FE-SEM", "FE-SEM-fused", "Tri/IM",
            "SEM/IM", "fused bytes/SEM", "SEM mem", "IM mem",
        ],
    );
    for &ds in datasets {
        let mut coo = cfg.gen(ds);
        if ds.directed() {
            coo.symmetrize(); // eigensolving needs a symmetric operator
        }
        for &nev in nevs {
            let im = run_eigensolver(cfg, &coo, nev, "fe-im");
            let tri = run_eigensolver(cfg, &coo, nev, "trilinos");
            let sem = run_eigensolver(cfg, &coo, nev, "fe-sem");
            let semf = run_eigensolver(cfg, &coo, nev, "fe-sem-fused");
            let sem_bytes = (sem.bytes_read + sem.bytes_written).max(1);
            let semf_bytes = semf.bytes_read + semf.bytes_written;
            t.row(vec![
                ds.name().into(),
                format!("{nev}"),
                secs(im.runtime),
                secs(tri.runtime),
                secs(sem.runtime),
                secs(semf.runtime),
                ratio(im.runtime / tri.runtime),
                ratio(im.runtime / sem.runtime),
                ratio(semf_bytes as f64 / sem_bytes as f64),
                fmt_bytes(sem.peak_mem),
                fmt_bytes(im.peak_mem),
            ]);
        }
    }
    t.note("paper shape: FE-SEM ≥ 0.4 of FE-IM (≈0.5 for small nev); FE-IM beats Trilinos; SEM memory ≈ flat in nev");
    t.note("FE-SEM-fused = the default fused+streamed §3.4 configuration; FE-IM/FE-SEM/Trilinos rows select eager explicitly (the ablation reference); 'fused bytes/SEM' < 1.0 shows the I/O saving");
    t
}

// ---------------------------------------------------------------- Table 3

/// Table 3: the billion-node page-graph run (scaled), via SVD of the
/// directed adjacency matrix, plus a projection to paper scale.
pub fn table3(cfg: &BenchCfg, nev: usize) -> Table {
    let mut t = Table::new(
        "Table 3: page-graph SVD (scaled billion-node run)",
        &["quantity", "measured (scaled)", "paper (full scale)"],
    );
    let coo = cfg.gen(Dataset::Page);
    let fs = cfg.timed_safs();
    let ctx = cfg.dense_ctx_native(fs.clone(), true);
    let op = crate::eigen::build_gram_operator(
        &coo,
        cfg.tile_dim,
        Some(&fs),
        SpmmOpts::default(),
        cfg.threads,
    );
    // §4.3.2: block size 2, 2·ev blocks for the page graph.
    let ecfg = EigenConfig {
        nev,
        block_size: 2,
        num_blocks: 2 * nev,
        tol: 1e-6,
        max_restarts: 300,
        which: Which::LargestAlgebraic,
        seed: cfg.seed,
        compute_eigenvectors: false,
        refine_steps: 0,
        warm_start: None,
    };
    let before = fs.stats();
    let (res, runtime) = time_it(|| crate::eigen::svd(&op, &ctx, &ecfg));
    let delta = fs.stats().delta_since(&before);
    let (pv, pe) = Dataset::Page.paper_scale();
    t.row(vec!["vertices".into(), format!("{}", coo.n_rows), format!("{pv}")]);
    t.row(vec!["edges".into(), format!("{}", coo.nnz()), format!("{pe}")]);
    t.row(vec!["#singular values".into(), format!("{}", nev), "8".into()]);
    t.row(vec!["converged".into(), format!("{}", res.converged), "yes".into()]);
    t.row(vec!["runtime".into(), secs(runtime), "4.2 hours".into()]);
    t.row(vec![
        "memory".into(),
        fmt_bytes(ctx.mem.peak()),
        "120GB".into(),
    ]);
    t.row(vec![
        "read".into(),
        fmt_bytes(delta.bytes_read),
        "145TB".into(),
    ]);
    t.row(vec![
        "write".into(),
        fmt_bytes(delta.bytes_written),
        "4TB".into(),
    ]);
    t.row(vec![
        "read:write ratio".into(),
        format!("{:.1}", delta.bytes_read as f64 / delta.bytes_written.max(1) as f64),
        format!("{:.1}", 145.0 / 4.0),
    ]);
    t.note(format!(
        "scaled by {:.2e}; the read:write ratio and flat memory are the scale-free quantities to compare",
        cfg.scale
    ));
    t.note(format!("top singular values: {:?}", res.singular_values));
    t
}

// ----------------------------------------------------------- Fig 13

/// Measure the resident-session multi-tenant batching ablation: `width`
/// identical EM eigensolve jobs served concurrently through one
/// [`SolverPool`] over one [`GraphSession`], per cross-apply image-cache
/// budget {off, one image}.  Identical queries (same seed) keep the
/// jobs in lockstep so every batched sweep runs at full width.  Returns
/// `(width, cache_label, io_delta, attributed_image_bytes, wall_secs,
/// worst_residual, sweeps)` rows — the raw data behind
/// [`fig13_batching`], also pinned by the I/O-accounting regression
/// tests.
pub fn fig13_batching_data(
    cfg: &BenchCfg,
    n_scale: f64,
    widths: &[usize],
) -> Vec<(usize, &'static str, IoStats, u64, f64, f64, u64)> {
    let mut scaled = cfg.clone();
    scaled.scale *= n_scale;
    let mut coo = scaled.gen(Dataset::Friendster);
    if Dataset::Friendster.directed() {
        coo.symmetrize();
    }
    // The image byte total is a function of the layout alone, so a
    // throwaway in-memory build sizes the cache budgets.
    let image_bytes = scaled.build_im(&coo).storage_bytes();
    let job = JobSpec {
        name: "q".into(),
        em: true,
        warm: false,
        cfg: EigenConfig {
            nev: 4,
            block_size: 2,
            num_blocks: 8,
            tol: 1e-6,
            max_restarts: 200,
            which: Which::LargestMagnitude,
            seed: scaled.seed,
            compute_eigenvectors: false,
            refine_steps: 0,
            warm_start: None,
        },
    };
    let mut rows = Vec::new();
    for (cache_label, budget) in [("off", 0u64), ("full image", image_bytes)] {
        for &width in widths {
            let mut per = scaled.clone();
            per.image_cache = budget;
            let fs = Safs::new(per.safs_config());
            let m = per.build_sem(&coo, &fs, "fig13");
            let sess = GraphSession::eigen(
                "fig13",
                fs.clone(),
                m,
                SpmmOpts::default(),
                per.threads,
                per.interval_rows,
            );
            let specs: Vec<JobSpec> = (0..width)
                .map(|j| {
                    let mut s = job.clone();
                    s.name = format!("j{j}");
                    s
                })
                .collect();
            let pool = SolverPool::new(0, width);
            let before = fs.stats();
            let (reports, wall) = time_it(|| pool.run(&sess, &specs));
            let io = fs.stats().delta_since(&before);
            assert!(
                reports.iter().all(|r| r.converged),
                "fig13 job failed to converge at width {width}"
            );
            let image: u64 = reports.iter().map(|r| r.image_bytes).sum();
            let worst = reports
                .iter()
                .flat_map(|r| r.residuals.iter().copied())
                .fold(0.0f64, f64::max);
            rows.push((
                width,
                cache_label,
                io,
                image,
                wall,
                worst,
                sess.batcher().sweeps(),
            ));
        }
    }
    rows
}

/// Figure 13 (beyond the paper): the resident-session batching ablation
/// — `k` identical EM eigensolve jobs served by one [`GraphSession`],
/// width {1, 2, 4} × image-cache budget {off, one image}.  With
/// batching, every streamed image sweep multiplies all pending panels,
/// so the per-job read cost falls as width grows; a full-image cache
/// already makes warm sweeps image-free, narrowing batching's saving to
/// the cold pass.
pub fn fig13_batching(cfg: &BenchCfg, n_scale: f64, widths: &[usize]) -> Table {
    let mut t = Table::new(
        "Figure 13: multi-tenant SpMM batching (k identical EM eigensolves, one session)",
        &[
            "cache", "width", "read", "image read", "written", "sweeps", "wall",
            "worst residual", "read/job vs width 1",
        ],
    );
    let rows = fig13_batching_data(cfg, n_scale, widths);
    let mut base_per_job = 1.0f64;
    for (width, cache_label, io, image, wall, worst, sweeps) in &rows {
        let per_job = io.bytes_read as f64 / (*width).max(1) as f64;
        if *width == widths[0] {
            base_per_job = per_job.max(1.0);
        }
        t.row(vec![
            (*cache_label).into(),
            format!("{width}"),
            fmt_bytes(io.bytes_read),
            fmt_bytes(*image),
            fmt_bytes(io.bytes_written),
            format!("{sweeps}"),
            secs(*wall),
            format!("{worst:.2e}"),
            ratio(per_job / base_per_job),
        ]);
    }
    t.note(
        "every job's spectrum is bitwise identical at every width and budget (tests/props.rs): \
         batching changes only the I/O schedule — one streamed image sweep serves all pending \
         applies, so total image traffic stays ~O(sweeps x image) instead of \
         O(width x sweeps x image); 'read/job vs width 1' compares within each cache group",
    );
    t
}

// ----------------------------------------------------------- Fig 14

/// Dynamic-graph churn ablation data: a symmetrized R-MAT graph held
/// resident in one eigen [`GraphSession`]; per churn depth, a prior
/// solve stashes its converged basis, `depth` symmetric delta waves
/// mutate the resident image through the overlay
/// ([`GraphSession::apply_deltas`], compaction at the configured
/// threshold), then the perturbed graph is re-solved cold (random
/// start) and warm (seeded from the stashed basis).  Returns
/// `(depth, churn_nnz, compacted, cold, warm)` rows — the raw data
/// behind [`fig14_churn`].
pub fn fig14_churn_data(
    cfg: &BenchCfg,
    depths: &[usize],
    per_wave: usize,
) -> Vec<(usize, u64, bool, JobReport, JobReport)> {
    // Same effective |V| as the other resident-session ablations
    // (≈ friendster at 16x bench scale).
    let n = ((65_000_000.0 * cfg.scale * 16.0) as u64).max(512);
    let m = 8 * n;
    let mk = |seed: u64, warm: bool, vecs: bool, name: &str| JobSpec {
        name: name.into(),
        em: false,
        warm,
        cfg: EigenConfig {
            nev: 4,
            block_size: 2,
            num_blocks: 8,
            tol: 1e-6,
            max_restarts: 300,
            which: Which::LargestMagnitude,
            seed,
            compute_eigenvectors: vecs,
            refine_steps: 0,
            warm_start: None,
        },
    };
    let mut rows = Vec::new();
    for &depth in depths {
        let (base, waves) = rmat_churn(n, m, depth, per_wave, cfg.seed);
        let fs = cfg.timed_safs();
        let a = cfg.build_sem(&base, &fs, "fig14");
        let sess = GraphSession::eigen(
            "fig14",
            fs,
            a,
            SpmmOpts::default(),
            cfg.threads,
            cfg.interval_rows,
        );
        let pool = SolverPool::new(0, 1);
        let prior = pool.run(&sess, &[mk(cfg.seed, false, true, "prior")]);
        assert!(prior[0].converged, "fig14 prior solve did not converge");
        let mut churn = 0u64;
        for w in &waves {
            let st = sess.apply_deltas(w, cfg.delta_compact);
            churn += st.inserted + st.updated + st.deleted;
        }
        let compacted = sess.batcher().matrix().overlay.is_none();
        let cold = pool.run(&sess, &[mk(cfg.seed, false, false, "cold")]).remove(0);
        let warm = pool.run(&sess, &[mk(cfg.seed, true, false, "warm")]).remove(0);
        rows.push((depth, churn, compacted, cold, warm));
    }
    rows
}

/// Figure 14 (beyond the paper): the dynamic-graph churn ablation —
/// delta-overlay mutation depth × {cold, warm} re-solve.  A warm
/// re-solve seeds Krylov–Schur from the pre-churn converged basis, so
/// on small perturbations it reconverges in strictly fewer restarts
/// (and operator applies) than the cold random start; as churn deepens
/// the stale basis loses its advantage.
pub fn fig14_churn(cfg: &BenchCfg, depths: &[usize], per_wave: usize) -> Table {
    let mut t = Table::new(
        "Figure 14: dynamic-graph churn — warm vs cold re-solves (delta overlay, R-MAT)",
        &[
            "depth", "churn nnz", "compacted", "cold restarts", "warm restarts",
            "cold applies", "warm applies", "warm/cold applies",
        ],
    );
    for (depth, churn, compacted, cold, warm) in fig14_churn_data(cfg, depths, per_wave) {
        assert!(cold.converged && warm.converged, "fig14 re-solve did not converge");
        t.row(vec![
            format!("{depth}"),
            format!("{churn}"),
            format!("{compacted}"),
            format!("{}", cold.restarts),
            format!("{}", warm.restarts),
            format!("{}", cold.operator_applies),
            format!("{}", warm.operator_applies),
            ratio(warm.operator_applies as f64 / cold.operator_applies.max(1) as f64),
        ]);
    }
    t.note(
        "cold and warm agree on the spectrum at every depth (tests/props.rs); the mutated \
         image is served through the base-geometry delta overlay, compacting into a fresh \
         base once churn exceeds --delta-compact of the base nnz",
    );
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> BenchCfg {
        BenchCfg {
            scale: 3e-6,
            threads: 2,
            dilation: 4.0,
            tile_dim: 64,
            interval_rows: 256,
            seed: 1,
            read_ahead: 2,
            image_cache: 0,
            queue_depth: 32,
            io_backend: crate::safs::IoBackend::Queued,
            storage_precision: StoragePrecision::F64,
            delta_compact: 0.25,
        }
    }

    #[test]
    fn table2_smoke() {
        let t = table2(&tiny_cfg());
        assert_eq!(t.rows.len(), 4);
    }

    #[test]
    fn fig6_smoke() {
        let t = fig6(&tiny_cfg(), &[Dataset::Twitter], &[2]);
        assert_eq!(t.rows.len(), 7); // 7 cumulative stages
        assert!(t.render().contains("+SCSR+COO"));
    }

    #[test]
    fn fig7_fig8_smoke() {
        let t = fig7(&tiny_cfg(), &[1, 4]);
        assert_eq!(t.rows.len(), 2);
        let t = fig8(&tiny_cfg());
        assert_eq!(t.rows.len(), 6);
    }

    #[test]
    fn fig9_smoke() {
        let t = fig9(&tiny_cfg(), 1000, 8, 2);
        assert_eq!(t.rows.len(), 6);
    }

    #[test]
    fn fig13_batching_smoke_shares_cold_sweeps() {
        let rows = fig13_batching_data(&tiny_cfg(), 16.0, &[1, 2]);
        assert_eq!(rows.len(), 4);
        // Cache-off group: 2 batched jobs must read strictly less than
        // 2x one job (the image sweeps are shared, only the per-job
        // subspace traffic doubles).
        let (w1, w2) = (&rows[0], &rows[1]);
        assert!(
            w2.2.bytes_read < 2 * w1.2.bytes_read,
            "batched width 2 must undercut 2x width 1: {} vs 2x{}",
            w2.2.bytes_read,
            w1.2.bytes_read
        );
        let t = fig13_batching(&tiny_cfg(), 16.0, &[1, 2]);
        assert_eq!(t.rows.len(), 4);
        assert!(t.render().contains("worst residual"));
    }

    #[test]
    fn fig9_fusion_smoke_and_saving() {
        let rows = fig9_fusion_data(&tiny_cfg(), 2000, 8, 2);
        assert_eq!(rows.len(), 2);
        let (eager, fused) = (&rows[0].2, &rows[1].2);
        assert!(
            fused.total_bytes() < eager.total_bytes(),
            "fusion must reduce SAFS bytes: {} vs {}",
            fused.total_bytes(),
            eager.total_bytes()
        );
        let t = fig9_fusion(&tiny_cfg(), 2000, 8, 2);
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn fig9_stream_smoke_strictly_fewer_bytes_and_memory() {
        // Scale up so the subspace spans several intervals — streaming
        // is the identity transformation on a single-interval matrix.
        let rows = fig9_stream_data(&tiny_cfg(), 16.0, 4);
        assert_eq!(rows.len(), 2);
        let (eager, streamed) = (&rows[0], &rows[1]);
        assert!(
            streamed.2.total_bytes() < eager.2.total_bytes(),
            "streamed must move strictly fewer bytes: {} vs {}",
            streamed.2.total_bytes(),
            eager.2.total_bytes()
        );
        assert!(
            streamed.3 < eager.3,
            "streamed peak dense {} must undercut eager {}",
            streamed.3,
            eager.3
        );
        let t = fig9_stream(&tiny_cfg(), 16.0, 4);
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn fig9_gram_smoke_fewer_bytes_and_memory() {
        // The page graph is large enough at base scale that the subspace
        // spans dozens of intervals (streaming is the identity
        // transformation on a single-interval matrix).
        let rows = fig9_gram_data(&tiny_cfg(), 1.0, 4);
        assert_eq!(rows.len(), 2);
        let (eager, streamed) = (&rows[0], &rows[1]);
        assert!(
            streamed.2.total_bytes() < eager.2.total_bytes(),
            "two-hop must move strictly fewer bytes: {} vs {}",
            streamed.2.total_bytes(),
            eager.2.total_bytes()
        );
        assert!(
            streamed.3 < eager.3,
            "two-hop peak dense {} must undercut eager {}",
            streamed.3,
            eager.3
        );
        assert!(streamed.4 > 0, "staging peak must be recorded");
        assert_eq!(eager.4, 0, "eager apply has no staging ring");
        let t = fig9_gram(&tiny_cfg(), 1.0, 4);
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn fig9_readahead_smoke_identical_bytes() {
        // Scale up so the image spans several intervals; depth must not
        // change what is read, only when.
        let rows = fig9_readahead_data(&tiny_cfg(), 16.0, 2, &[0, 2]);
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[0].2.bytes_read, rows[1].2.bytes_read,
            "read-ahead must not change total bytes"
        );
        let t = fig9_readahead(&tiny_cfg(), 16.0, 2);
        assert_eq!(t.rows.len(), 3);
        assert!(t.render().contains("io wait"));
    }

    #[test]
    fn fig9_imgcache_smoke_full_budget_makes_warm_applies_image_free() {
        // Scale up so the image spans several intervals (the walk is an
        // actual sequence, not a single range).
        let rows = fig9_imgcache_data(&tiny_cfg(), 16.0, 2, 3);
        assert_eq!(rows.len(), 3);
        let (off, full) = (&rows[0], &rows[2]);
        // Budget 0: the cache is inert — nothing counted, warm applies
        // re-read like cold ones.
        assert_eq!(off.3.cache_hit_bytes, 0, "disabled cache must not hit");
        assert_eq!(off.4, 0, "disabled cache must hold nothing");
        // Full-image budget: both warm applies serve the whole image
        // from RAM (2 × image of hits) and read strictly fewer bytes
        // than the cache-off warm applies (only the subspace remains).
        assert_eq!(
            full.3.cache_hit_bytes,
            2 * full.1,
            "warm applies must hit the whole image twice"
        );
        assert!(
            full.3.bytes_read < off.3.bytes_read,
            "residency must cut warm traffic: {} vs {}",
            full.3.bytes_read,
            off.3.bytes_read
        );
        // Every budget: resident cache bytes stay within the budget.
        for (_, budget, _, _, peak) in &rows {
            assert!(peak <= budget, "cache peak {peak} exceeds budget {budget}");
        }
        let t = fig9_imgcache(&tiny_cfg(), 16.0, 2);
        assert_eq!(t.rows.len(), 3);
        assert!(t.render().contains("hit share"));
    }

    #[test]
    fn fig9_precision_smoke_fewer_bytes_same_iterations() {
        // Scale up so the subspace spans several intervals and the image
        // several tile rows.
        let rows = fig9_precision_data(&tiny_cfg(), 16.0, 2);
        assert_eq!(rows.len(), 2);
        let (f64r, f32r) = (&rows[0], &rows[1]);
        assert_eq!(f64r.0, "f64");
        assert_eq!(f32r.0, "f32");
        // Pinned iterations: the byte columns compare like for like.
        assert_eq!(f64r.4, f32r.4, "restart pinning must equalize applies");
        // Friendster is unweighted, so the image is byte-identical and
        // the saving is purely the halved subspace traffic.
        assert_eq!(f64r.1, f32r.1, "unweighted image must not change size");
        assert!(
            f32r.2.total_bytes() < f64r.2.total_bytes(),
            "f32 storage must move strictly fewer bytes: {} vs {}",
            f32r.2.total_bytes(),
            f64r.2.total_bytes()
        );
        // Residuals stay finite and meaningful under narrowed storage.
        assert!(f32r.3.is_finite() && f32r.3 > 0.0);
        let t = fig9_precision(&tiny_cfg(), 16.0, 2);
        assert_eq!(t.rows.len(), 2);
        assert!(t.render().contains("worst residual"));
    }

    #[test]
    fn fig10_fig11_smoke() {
        let t = fig10(&tiny_cfg(), 1000, 2, &[4, 8]);
        assert_eq!(t.rows.len(), 2);
        let t = fig11(&tiny_cfg(), 1000, 2, &[4]);
        assert_eq!(t.rows.len(), 1);
        // The queued engine's gauge columns: peak submission-queue depth
        // and the busy-spin share of io wait.
        let qd_col = t.headers.iter().position(|h| h == "qd").unwrap();
        assert!(t.headers.iter().any(|h| h == "poll"));
        let qd: u64 = t.rows[0][qd_col].parse().unwrap();
        assert!(qd >= 1, "EM dense MM must keep at least one request in flight");
    }

    #[test]
    fn fig14_churn_smoke_warm_beats_cold_on_small_churn() {
        let rows = fig14_churn_data(&tiny_cfg(), &[1], 6);
        assert_eq!(rows.len(), 1);
        let (depth, churn, _, cold, warm) = &rows[0];
        assert_eq!(*depth, 1);
        assert!(*churn > 0, "the wave must mutate the resident image");
        assert!(cold.converged && warm.converged);
        // The acceptance criterion: on a small perturbation the warm
        // re-solve reconverges in strictly fewer restarts than cold.
        assert!(
            warm.restarts < cold.restarts,
            "warm {} must undercut cold {}",
            warm.restarts,
            cold.restarts
        );
        // And on the same spectrum.
        for (a, b) in warm.values.iter().zip(&cold.values) {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1.0), "{a} vs {b}");
        }
        let t = fig14_churn(&tiny_cfg(), &[1], 6);
        assert_eq!(t.rows.len(), 1);
        assert!(t.render().contains("warm restarts"));
    }

    #[test]
    fn fig12_smoke() {
        let t = fig12(&tiny_cfg(), &[2], &[Dataset::Friendster]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn table3_smoke() {
        let t = table3(&tiny_cfg(), 2);
        assert!(t.rows.len() >= 8);
    }
}
