//! Tall-and-skinny (TAS) dense matrices — the vector subspace (§3.4).
//!
//! A TAS matrix holds `block size` vectors of the Krylov subspace
//! (n rows × b cols).  It is partitioned into **row intervals**; inside an
//! interval elements are **column-major** (Figure 4b) so individual
//! columns are easy to access.  Backing is either memory (FE-IM) or one
//! SAFS file per matrix (FE-EM, §3.4.1), with the §3.4.4 matrix cache:
//! the most recent `cache_slots` EM matrices stay resident in RAM (dirty
//! intervals are flushed on eviction), which is what saves most of the
//! SSD writes during reorthogonalization.
//!
//! # Storage precision
//!
//! Each matrix carries a serialized **element width** fixed at creation
//! from [`crate::safs::StoragePrecision`] (`--precision`): 8 bytes (f64,
//! the default) or 4 (f32).  The precision contract is storage-only —
//! every in-RAM interval is `Vec<f64>` and every accumulation runs in
//! f64; under f32 storage, values are narrowed exactly once at the store
//! boundary ([`TasMatrix::store_interval`] /
//! [`TasMatrix::update_interval`] round through f32 even while resident,
//! so cached FE-IM bits equal FE-EM bits and eviction flushes are
//! lossless) and widened back to f64 on every load.  Subspace I/O is
//! therefore exactly half the f64 bytes, results are deterministic
//! (bitwise-reproducible run-to-run), and the f64 default is
//! bitwise-identical to the pre-precision behaviour.  A
//! [`DenseCtx::scoped_full_precision`] scope forces full-width storage
//! for matrices created inside it — the eigensolver's f64 iterative
//! refinement uses it so refined Ritz pairs are never floored by f32
//! storage.

use super::kernels::{DenseKernels, NativeKernels};
use crate::metrics::{MemTracker, PhaseIo};
use crate::safs::{BufferPool, FileHandle, Safs, SafsConfig};
use crate::util::rng::Rng;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, Weak};

/// Cast an 8-byte-aligned little-endian byte slice to `&[f64]`.
pub fn cast_f64s(bytes: &[u8]) -> &[f64] {
    assert_eq!(bytes.len() % 8, 0);
    assert_eq!(bytes.as_ptr() as usize % 8, 0, "interval buffer misaligned");
    // SAFETY: alignment/length checked; all bit patterns are valid f64.
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const f64, bytes.len() / 8) }
}

/// View an f64 slice as bytes (always safe).
pub fn f64s_as_bytes(xs: &[f64]) -> &[u8] {
    // SAFETY: f64 has no padding; alignment of u8 is 1.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 8) }
}

/// Round every element to its nearest f32 (the f32-storage store
/// boundary).  Exact round-trip: a value that already equals its f32
/// rounding is unchanged, so applying this twice is idempotent.
pub fn round_to_f32(xs: &mut [f64]) {
    for x in xs.iter_mut() {
        *x = *x as f32 as f64;
    }
}

/// Serialize an f64 interval at the given element width: f64 LE bytes
/// (`elem == 8`) or f32 LE bytes (`elem == 4`, the f32-storage write
/// boundary — lossless whenever the data already rounded through f32).
fn serialize_interval(data: &[f64], elem: usize) -> Vec<u8> {
    match elem {
        8 => f64s_as_bytes(data).to_vec(),
        4 => {
            let mut out = Vec::with_capacity(data.len() * 4);
            for &x in data {
                out.extend_from_slice(&(x as f32).to_le_bytes());
            }
            out
        }
        _ => unreachable!("unsupported element width {elem}"),
    }
}

/// Widen one interval's raw storage bytes to the f64 LE bytes
/// [`IntervalGuard::Owned`] holds — identity for f64 storage, an
/// f32→f64 decode through a pooled buffer for f32 storage.  This is the
/// single load-boundary widening point; callers that bypass
/// [`TasMatrix::load_interval`] (the fused walks' scheduler reads) route
/// their bytes through here.
pub fn widen_stored_bytes(bytes: Vec<u8>, elem: usize, pool: &mut BufferPool) -> Vec<u8> {
    if elem == 8 {
        return bytes;
    }
    assert_eq!(elem, 4, "unsupported element width {elem}");
    assert_eq!(bytes.len() % 4, 0);
    let n = bytes.len() / 4;
    let mut wide = pool.get(n * 8);
    for (i, ch) in bytes.chunks_exact(4).enumerate() {
        let v = f32::from_le_bytes(ch.try_into().unwrap()) as f64;
        wide[i * 8..(i + 1) * 8].copy_from_slice(&v.to_le_bytes());
    }
    pool.put(bytes);
    wide
}

/// Shared configuration + services for all dense matrices of one solver
/// instance.
pub struct DenseCtx {
    pub fs: Arc<Safs>,
    /// Subspace on SSDs (FE-EM) or in memory (FE-IM).
    pub em: bool,
    /// Rows per interval (same for every matrix in the context).
    pub interval_rows: usize,
    pub threads: usize,
    /// TAS matrices per group in many-matrix operations (§3.4.3, Fig. 5).
    pub group_size: usize,
    /// Number of EM matrices kept resident (§3.4.4; 0 disables caching).
    pub cache_slots: usize,
    pub kernels: Arc<dyn DenseKernels>,
    pub mem: Arc<MemTracker>,
    /// Per-phase SAFS byte accounting (the solver scopes its spmm /
    /// ortho / restart sections through this).
    pub io_phases: PhaseIo,
    /// When set (the **default** since the §3.4 soak completed), the
    /// eigensolver layers route their MultiVec chains through the
    /// lazy-evaluation pipeline ([`crate::dense::fused`]) instead of the
    /// eager Table-1 ops.  The eager path stays available as the
    /// reference implementation — opt out with
    /// [`DenseCtx::set_eager`] (CLI `--eager`) for differential testing.
    fused: AtomicBool,
    /// When set with `fused` (also the **default**), operator applies
    /// use the streamed ConvLayout→SpMM→ConvLayout boundary: the SpMM
    /// output flows interval-by-interval into the consuming pipeline
    /// instead of materializing full-height dense blocks
    /// ([`crate::spmm::StreamedSpmm`]; the SVD path chains two hops via
    /// [`crate::spmm::ChainedGramSpmm`]).  Layouts that cannot stream
    /// fall back to the eager apply automatically.
    streamed: AtomicBool,
    /// When set, matrices created in this context serialize at full
    /// width regardless of [`crate::safs::SafsConfig::storage_precision`]
    /// — the f64 iterative-refinement scope
    /// ([`DenseCtx::scoped_full_precision`]).
    full_prec: AtomicBool,
    /// Name prefix of EM backing files created by this context
    /// (`<tag>-<id>`; default `tas`).  The resident solver service gives
    /// each job's context a unique tag so [`crate::safs::Safs::file_bytes`]
    /// prefix sums attribute a job's private subspace traffic exactly.
    file_tag: Mutex<String>,
    ids: AtomicU64,
    lru: Mutex<VecDeque<Weak<MatInner>>>,
}

impl DenseCtx {
    /// Default interval: 512K rows × 8 B ⇒ 4 MiB per column — the paper's
    /// "tens of megabytes" per interval at b=4.
    pub const DEFAULT_INTERVAL_ROWS: usize = 512 * 1024;

    pub fn new(fs: Arc<Safs>, em: bool) -> Arc<DenseCtx> {
        Arc::new(DenseCtx {
            fs,
            em,
            interval_rows: Self::DEFAULT_INTERVAL_ROWS,
            threads: 4,
            group_size: 8,
            cache_slots: 1,
            kernels: Arc::new(NativeKernels),
            mem: Arc::new(MemTracker::default()),
            io_phases: PhaseIo::new(),
            fused: AtomicBool::new(true),
            streamed: AtomicBool::new(true),
            full_prec: AtomicBool::new(false),
            file_tag: Mutex::new("tas".to_string()),
            ids: AtomicU64::new(1),
            lru: Mutex::new(VecDeque::new()),
        })
    }

    /// Builder-style tweaks (used by tests and the bench harness).
    pub fn with(
        fs: Arc<Safs>,
        em: bool,
        interval_rows: usize,
        threads: usize,
        group_size: usize,
        cache_slots: usize,
        kernels: Arc<dyn DenseKernels>,
    ) -> Arc<DenseCtx> {
        Arc::new(DenseCtx {
            fs,
            em,
            interval_rows,
            threads,
            group_size,
            cache_slots,
            kernels,
            mem: Arc::new(MemTracker::default()),
            io_phases: PhaseIo::new(),
            fused: AtomicBool::new(true),
            streamed: AtomicBool::new(true),
            full_prec: AtomicBool::new(false),
            file_tag: Mutex::new("tas".to_string()),
            ids: AtomicU64::new(1),
            lru: Mutex::new(VecDeque::new()),
        })
    }

    /// A sibling context with the same configuration but the given
    /// memory tracker.  The resident solver pool derives every job's
    /// context through this so all concurrent jobs charge one shared
    /// tracker — the budget the pool's admission control reasons about.
    /// Path toggles (fused/streamed) carry over at their current values;
    /// id space, LRU cache and per-phase I/O accounting start fresh.
    pub fn share_mem(self: &Arc<Self>, mem: Arc<MemTracker>) -> Arc<DenseCtx> {
        Arc::new(DenseCtx {
            fs: self.fs.clone(),
            em: self.em,
            interval_rows: self.interval_rows,
            threads: self.threads,
            group_size: self.group_size,
            cache_slots: self.cache_slots,
            kernels: self.kernels.clone(),
            mem,
            io_phases: PhaseIo::new(),
            fused: AtomicBool::new(self.is_fused()),
            streamed: AtomicBool::new(self.is_streamed()),
            full_prec: AtomicBool::new(false),
            file_tag: Mutex::new(self.file_tag()),
            ids: AtomicU64::new(1),
            lru: Mutex::new(VecDeque::new()),
        })
    }

    /// In-memory context over an untimed SAFS (tests).
    pub fn mem_for_tests(interval_rows: usize) -> Arc<DenseCtx> {
        let fs = Safs::new(SafsConfig::untimed());
        DenseCtx::with(fs, false, interval_rows, 2, 3, 1, Arc::new(NativeKernels))
    }

    pub fn em_for_tests(interval_rows: usize) -> Arc<DenseCtx> {
        let fs = Safs::new(SafsConfig::untimed());
        DenseCtx::with(fs, true, interval_rows, 2, 3, 1, Arc::new(NativeKernels))
    }

    /// Whether the eigensolver layers should use the §3.4
    /// lazy-evaluation fused pipeline (the default configuration).
    pub fn is_fused(&self) -> bool {
        self.fused.load(Ordering::Relaxed)
    }

    /// Toggle the fused pipeline (runtime-switchable so ablations can
    /// compare both paths over one context).
    pub fn set_fused(&self, on: bool) {
        self.fused.store(on, Ordering::Relaxed);
    }

    /// Whether operator applies should use the streamed SpMM boundary
    /// (only honoured in fused mode — the stream feeds a pipeline walk).
    /// On by default together with `fused`.
    pub fn is_streamed(&self) -> bool {
        self.streamed.load(Ordering::Relaxed)
    }

    /// Toggle the streamed operator boundary.
    pub fn set_streamed(&self, on: bool) {
        self.streamed.store(on, Ordering::Relaxed);
    }

    /// Opt out of the default fused + streamed configuration in one
    /// call: route every MultiVec chain through the eager Table-1
    /// reference ops and every operator apply through the materialized
    /// ConvLayout→SpMM→ConvLayout boundary.  Ablations and differential
    /// tests select the reference path explicitly through this instead
    /// of inheriting it from a context default.
    pub fn set_eager(&self, on: bool) {
        self.set_fused(!on);
        self.set_streamed(!on);
    }

    /// The serialized element width new matrices get right now: the
    /// configured [`crate::safs::SafsConfig::storage_precision`], unless
    /// a full-precision scope is active.
    pub fn storage_elem_bytes(&self) -> usize {
        if self.full_prec.load(Ordering::Relaxed) {
            8
        } else {
            self.fs.cfg().storage_precision.elem_bytes()
        }
    }

    /// Run `f` with full-width storage forced for every matrix created
    /// inside it (used by the solver's f64 iterative refinement so
    /// refined Ritz pairs are not floored by f32 storage).  Restores the
    /// previous state on exit.
    pub fn scoped_full_precision<T>(&self, f: impl FnOnce() -> T) -> T {
        let was = self.full_prec.swap(true, Ordering::Relaxed);
        let out = f();
        self.full_prec.store(was, Ordering::Relaxed);
        out
    }

    /// The EM backing-file name prefix of this context (default `tas`).
    pub fn file_tag(&self) -> String {
        self.file_tag.lock().unwrap().clone()
    }

    /// Set the EM backing-file name prefix for matrices created from now
    /// on.  The resident solver service tags each job's context uniquely
    /// (e.g. `job3`) before the solve starts, so the job's subspace
    /// traffic is exactly the [`crate::safs::Safs::file_bytes`] sum of
    /// its prefix.  Tags of contexts sharing one filesystem must be
    /// distinct and prefix-free (no tag a prefix of another).
    pub fn set_file_tag(&self, tag: &str) {
        *self.file_tag.lock().unwrap() = tag.to_string();
    }

    fn next_id(&self) -> u64 {
        self.ids.fetch_add(1, Ordering::Relaxed)
    }

    /// Register a new resident EM matrix in the cache, evicting (flushing)
    /// the oldest beyond `cache_slots`.
    fn register_resident(&self, inner: &Arc<MatInner>) {
        let mut lru = self.lru.lock().unwrap();
        lru.push_back(Arc::downgrade(inner));
        while lru.len() > self.cache_slots {
            if let Some(w) = lru.pop_front() {
                if let Some(old) = w.upgrade() {
                    old.flush_and_evict();
                }
            }
        }
    }
}

/// Shared matrix state (so the cache LRU can hold weak references).
struct MatInner {
    id: u64,
    n_rows: usize,
    n_cols: usize,
    interval_rows: usize,
    /// Serialized bytes per element (8 = f64, 4 = f32), fixed at
    /// creation from the context's storage precision.  Applies at the
    /// store/load boundary only; resident data is always `Vec<f64>`.
    elem: usize,
    /// EM backing file; `None` for memory-backed matrices.
    file: Option<FileHandle>,
    /// Per-interval resident data (column-major).  Memory-backed matrices
    /// always have all slots populated.
    slots: Vec<Mutex<Option<Vec<f64>>>>,
    /// Whether writes currently target the resident slots.
    resident: AtomicBool,
    dirty: AtomicBool,
    fs: Arc<Safs>,
    mem: Arc<MemTracker>,
}

impl MatInner {
    fn n_intervals(&self) -> usize {
        self.n_rows.max(1).div_ceil(self.interval_rows)
    }

    fn interval_len(&self, iv: usize) -> usize {
        self.interval_rows.min(self.n_rows - iv * self.interval_rows)
    }

    fn byte_offset(&self, iv: usize) -> u64 {
        (iv * self.interval_rows * self.n_cols * self.elem) as u64
    }

    /// Write all dirty resident intervals to the file and drop them.
    fn flush_and_evict(&self) {
        if !self.resident.swap(false, Ordering::AcqRel) {
            return;
        }
        let dirty = self.dirty.load(Ordering::Acquire);
        for iv in 0..self.n_intervals() {
            let mut slot = self.slots[iv].lock().unwrap();
            if let Some(data) = slot.take() {
                if dirty {
                    if let Some(file) = &self.file {
                        // Lossless at any width: stores already rounded
                        // resident data through the storage precision.
                        let bytes = serialize_interval(&data, self.elem);
                        self.fs
                            .write_async(file.clone(), self.byte_offset(iv), bytes)
                            .wait();
                    }
                }
                self.mem.free((data.len() * 8) as u64);
            }
        }
        self.dirty.store(false, Ordering::Release);
    }
}

impl Drop for MatInner {
    fn drop(&mut self) {
        for slot in &self.slots {
            if let Some(data) = slot.lock().unwrap().take() {
                self.mem.free((data.len() * 8) as u64);
            }
        }
        if let Some(file) = &self.file {
            self.fs.delete(&file.name);
        }
    }
}

/// A tall-and-skinny dense matrix (one physical block of the subspace).
pub struct TasMatrix {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Identifies the *data* (§3.4.4): views that share data share the id.
    pub data_id: u64,
    ctx: Arc<DenseCtx>,
    inner: Arc<MatInner>,
}

impl TasMatrix {
    /// Allocate a zero matrix in the context's backing mode.
    pub fn zeros(ctx: &Arc<DenseCtx>, n_rows: usize, n_cols: usize) -> TasMatrix {
        Self::zeros_impl(ctx, n_rows, n_cols, true)
    }

    /// Like [`TasMatrix::zeros`], but for a matrix whose every interval
    /// will be fully overwritten before being read (a fused-pipeline
    /// target): the EM allocation is left *clean*, so a cache eviction
    /// before the overwrite flushes nothing, and no zero-fill is
    /// materialized on SSD.  Reads of never-written ranges still return
    /// zeros (SAFS files are sparse), so this is safe even if some
    /// interval is read before being stored.
    pub fn zeros_for_overwrite(ctx: &Arc<DenseCtx>, n_rows: usize, n_cols: usize) -> TasMatrix {
        Self::zeros_impl(ctx, n_rows, n_cols, false)
    }

    fn zeros_impl(
        ctx: &Arc<DenseCtx>,
        n_rows: usize,
        n_cols: usize,
        materialize_zeros: bool,
    ) -> TasMatrix {
        let id = ctx.next_id();
        let interval_rows = ctx.interval_rows;
        let n_intervals = n_rows.max(1).div_ceil(interval_rows);
        let em = ctx.em;
        let elem = ctx.storage_elem_bytes();
        let resident = !em || ctx.cache_slots > 0;
        let file = em.then(|| ctx.fs.create(&format!("{}-{id}", ctx.file_tag())));
        let slots: Vec<Mutex<Option<Vec<f64>>>> = (0..n_intervals)
            .map(|iv| {
                if resident {
                    let len = interval_rows.min(n_rows - iv * interval_rows) * n_cols;
                    ctx.mem.alloc((len * 8) as u64);
                    Mutex::new(Some(vec![0.0; len]))
                } else {
                    Mutex::new(None)
                }
            })
            .collect();
        if em && !resident && materialize_zeros {
            // Materialize zeros on SSD so later partial reads see zeros.
            for iv in 0..n_intervals {
                let len = interval_rows.min(n_rows - iv * interval_rows) * n_cols;
                let file = file.as_ref().unwrap();
                ctx.fs
                    .write_async(
                        file.clone(),
                        (iv * interval_rows * n_cols * elem) as u64,
                        vec![0u8; len * elem],
                    )
                    .wait();
            }
        }
        let inner = Arc::new(MatInner {
            id,
            n_rows,
            n_cols,
            interval_rows,
            elem,
            file,
            slots,
            resident: AtomicBool::new(resident),
            dirty: AtomicBool::new(resident && em && materialize_zeros),
            fs: ctx.fs.clone(),
            mem: ctx.mem.clone(),
        });
        if em && resident {
            ctx.register_resident(&inner);
        }
        TasMatrix { n_rows, n_cols, data_id: id, ctx: ctx.clone(), inner }
    }

    pub fn ctx(&self) -> &Arc<DenseCtx> {
        &self.ctx
    }

    pub fn id(&self) -> u64 {
        self.inner.id
    }

    pub fn n_intervals(&self) -> usize {
        self.inner.n_intervals()
    }

    pub fn interval_rows(&self) -> usize {
        self.inner.interval_rows
    }

    pub fn interval_len(&self, iv: usize) -> usize {
        self.inner.interval_len(iv)
    }

    pub fn is_resident(&self) -> bool {
        self.inner.resident.load(Ordering::Acquire)
    }

    /// Serialized bytes per element of this matrix's storage (8 = f64,
    /// 4 = f32) — fixed at creation from the context's
    /// [`crate::safs::StoragePrecision`] (or 8 inside a
    /// [`DenseCtx::scoped_full_precision`] scope).
    pub fn elem_bytes(&self) -> usize {
        self.inner.elem
    }

    pub fn same_data(&self, other: &TasMatrix) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner) || self.data_id == other.data_id
    }

    /// True when both handles refer to the same physical storage (the
    /// aliasing test the fused pipeline uses to load each operand's
    /// interval exactly once).
    pub fn shares_storage(&self, other: &TasMatrix) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }

    /// Force-flush resident data to the backing file (EM only).
    pub fn flush(&self) {
        self.inner.flush_and_evict();
    }

    /// Load interval `iv` (column-major `len × n_cols`).  Resident data is
    /// borrowed; external data is read through SAFS into a pooled buffer.
    pub fn load_interval<'a>(&'a self, iv: usize, pool: &mut BufferPool) -> IntervalGuard<'a> {
        {
            let guard = self.inner.slots[iv].lock().unwrap();
            if guard.is_some() {
                return IntervalGuard::Resident(guard);
            }
        }
        let file = self.inner.file.as_ref().expect("non-resident without file");
        let len = self.interval_len(iv) * self.n_cols;
        let elem = self.inner.elem;
        let buf = pool.get(len * elem);
        let bytes = self
            .ctx
            .fs
            .read_async(file.clone(), self.inner.byte_offset(iv), buf)
            .wait();
        IntervalGuard::Owned(widen_stored_bytes(bytes, elem, pool))
    }

    /// Byte range of interval `iv`'s load, for scheduling it through
    /// the unified interval-stream scheduler
    /// ([`crate::safs::WalkScheduler`]).  `None` when the matrix is
    /// resident (loads are RAM borrows, nothing to schedule) — callers
    /// build their schedule while residency is stable (no concurrent
    /// matrix creation) and fall back to [`TasMatrix::fetch_interval`]
    /// for unscheduled operands.
    pub fn interval_read_range(&self, iv: usize) -> Option<crate::safs::ReadRange> {
        if self.inner.resident.load(Ordering::Acquire) {
            return None;
        }
        let file = self.inner.file.as_ref()?;
        Some(crate::safs::ReadRange {
            file: file.clone(),
            offset: self.inner.byte_offset(iv),
            len: self.interval_len(iv) * self.n_cols * self.inner.elem,
        })
    }

    /// Begin an async load (the op pipeline issues all loads of an
    /// interval set before waiting on any — that is what lets a single
    /// worker keep every device of the array busy).
    pub fn fetch_interval<'a>(&'a self, iv: usize, pool: &mut BufferPool) -> Fetch<'a> {
        {
            let guard = self.inner.slots[iv].lock().unwrap();
            if guard.is_some() {
                return Fetch::Ready(IntervalGuard::Resident(guard));
            }
        }
        let file = self.inner.file.as_ref().expect("non-resident without file");
        let len = self.interval_len(iv) * self.n_cols;
        let elem = self.inner.elem;
        let buf = pool.get(len * elem);
        Fetch::Pending(
            self.ctx
                .fs
                .read_async(file.clone(), self.inner.byte_offset(iv), buf),
            elem,
        )
    }

    /// Store interval `iv`.  This is the precision write boundary: under
    /// f32 storage the data rounds through f32 here — including on the
    /// resident path, so cached bits equal what a store+load round trip
    /// would produce and eviction flushes are lossless.
    pub fn store_interval(&self, iv: usize, mut data: Vec<f64>) {
        debug_assert_eq!(data.len(), self.interval_len(iv) * self.n_cols);
        if self.inner.elem == 4 {
            round_to_f32(&mut data);
        }
        if self.inner.resident.load(Ordering::Acquire) {
            let mut slot = self.inner.slots[iv].lock().unwrap();
            match slot.as_mut() {
                Some(old) => *old = data,
                None => {
                    self.ctx.mem.alloc((data.len() * 8) as u64);
                    *slot = Some(data);
                }
            }
            self.inner.dirty.store(true, Ordering::Release);
        } else {
            let file = self.inner.file.as_ref().expect("non-resident without file");
            let bytes = serialize_interval(&data, self.inner.elem);
            self.ctx
                .fs
                .write_async(file.clone(), self.inner.byte_offset(iv), bytes)
                .wait();
        }
    }

    /// Mutate one resident interval in place (memory-backed fast path);
    /// falls back to load+store for external matrices.
    pub fn update_interval(
        &self,
        iv: usize,
        pool: &mut BufferPool,
        f: impl FnOnce(&mut [f64]),
    ) {
        if self.inner.resident.load(Ordering::Acquire) {
            let mut slot = self.inner.slots[iv].lock().unwrap();
            if let Some(data) = slot.as_mut() {
                f(data);
                if self.inner.elem == 4 {
                    // Same write boundary as store_interval: the
                    // resident fast path must not dodge the rounding.
                    round_to_f32(data);
                }
                self.inner.dirty.store(true, Ordering::Release);
                return;
            }
        }
        let mut data = self.load_interval(iv, pool).to_vec();
        f(&mut data);
        self.store_interval(iv, data);
    }

    // ---- whole-matrix helpers (tests, small n) ----

    /// Full contents, column-major over the whole matrix.
    pub fn to_colmajor(&self) -> Vec<f64> {
        let mut pool = BufferPool::new(false);
        let mut out = vec![0.0; self.n_rows * self.n_cols];
        for iv in 0..self.n_intervals() {
            let len = self.interval_len(iv);
            let base = iv * self.interval_rows();
            let g = self.load_interval(iv, &mut pool);
            let data: &[f64] = &g;
            for c in 0..self.n_cols {
                for r in 0..len {
                    out[c * self.n_rows + base + r] = data[c * len + r];
                }
            }
        }
        out
    }

    pub fn from_fn(
        ctx: &Arc<DenseCtx>,
        n_rows: usize,
        n_cols: usize,
        f: impl Fn(usize, usize) -> f64,
    ) -> TasMatrix {
        let m = TasMatrix::zeros(ctx, n_rows, n_cols);
        let mut pool = BufferPool::new(false);
        for iv in 0..m.n_intervals() {
            let len = m.interval_len(iv);
            let base = iv * m.interval_rows();
            let mut data = vec![0.0; len * n_cols];
            for c in 0..n_cols {
                for r in 0..len {
                    data[c * len + r] = f(base + r, c);
                }
            }
            let _ = &mut pool;
            m.store_interval(iv, data);
        }
        m
    }

    pub fn get(&self, r: usize, c: usize) -> f64 {
        let iv = r / self.interval_rows();
        let len = self.interval_len(iv);
        let mut pool = BufferPool::new(false);
        let g = self.load_interval(iv, &mut pool);
        g[c * len + (r - iv * self.interval_rows())]
    }
}

/// Borrowed or owned interval data.
pub enum IntervalGuard<'a> {
    Resident(MutexGuard<'a, Option<Vec<f64>>>),
    Owned(Vec<u8>),
}

impl<'a> std::ops::Deref for IntervalGuard<'a> {
    type Target = [f64];
    fn deref(&self) -> &[f64] {
        match self {
            IntervalGuard::Resident(g) => g.as_ref().unwrap(),
            IntervalGuard::Owned(bytes) => cast_f64s(bytes),
        }
    }
}

impl<'a> IntervalGuard<'a> {
    /// Recycle the owned byte buffer into the pool.
    pub fn recycle(self, pool: &mut BufferPool) {
        if let IntervalGuard::Owned(bytes) = self {
            pool.put(bytes);
        }
    }
}

/// An in-flight interval load.  A pending fetch remembers its matrix's
/// element width so [`Fetch::finish`] can widen f32-stored bytes to the
/// f64 bytes [`IntervalGuard::Owned`] holds.
pub enum Fetch<'a> {
    Ready(IntervalGuard<'a>),
    Pending(crate::safs::IoTicket, usize),
}

impl<'a> Fetch<'a> {
    pub fn finish(self) -> IntervalGuard<'a> {
        match self {
            Fetch::Ready(g) => g,
            Fetch::Pending(t, elem) => {
                let bytes = t.wait();
                let mut pool = BufferPool::new(false);
                IntervalGuard::Owned(widen_stored_bytes(bytes, elem, &mut pool))
            }
        }
    }
}

/// Loads one row interval of several (possibly aliasing) matrices,
/// issuing all SSD reads before waiting on any.
pub struct IntervalSet<'a> {
    guards: Vec<IntervalGuard<'a>>,
    /// operand index → guard index (aliased operands share a guard).
    map: Vec<usize>,
}

impl<'a> IntervalSet<'a> {
    pub fn load(mats: &[&'a TasMatrix], iv: usize, pool: &mut BufferPool) -> IntervalSet<'a> {
        let mut map = Vec::with_capacity(mats.len());
        let mut distinct: Vec<&'a TasMatrix> = Vec::new();
        for m in mats {
            match distinct.iter().position(|d| Arc::ptr_eq(&d.inner, &m.inner)) {
                Some(gi) => map.push(gi),
                None => {
                    map.push(distinct.len());
                    distinct.push(m);
                }
            }
        }
        let fetches: Vec<Fetch<'a>> =
            distinct.iter().map(|m| m.fetch_interval(iv, pool)).collect();
        let guards = fetches.into_iter().map(|f| f.finish()).collect();
        IntervalSet { guards, map }
    }

    pub fn get(&self, operand: usize) -> &[f64] {
        &self.guards[self.map[operand]]
    }

    pub fn recycle(self, pool: &mut BufferPool) {
        for g in self.guards {
            g.recycle(pool);
        }
    }
}

/// Fill a matrix with deterministic pseudo-random values (MvRandom).
pub fn mv_random(mat: &TasMatrix, seed: u64) {
    let mut pool = BufferPool::new(false);
    for iv in 0..mat.n_intervals() {
        let len = mat.interval_len(iv) * mat.n_cols;
        let mut rng = Rng::new(seed ^ (iv as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut data = vec![0.0; len];
        for x in data.iter_mut() {
            *x = rng.gen_f64_range(-0.5, 0.5);
        }
        let _ = &mut pool;
        mat.store_interval(iv, data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_from_fn_roundtrip_mem_and_em() {
        for em in [false, true] {
            let ctx = if em {
                DenseCtx::em_for_tests(64)
            } else {
                DenseCtx::mem_for_tests(64)
            };
            let m = TasMatrix::from_fn(&ctx, 150, 3, |r, c| (r * 10 + c) as f64);
            assert_eq!(m.n_intervals(), 3);
            assert_eq!(m.get(0, 0), 0.0);
            assert_eq!(m.get(149, 2), 1492.0);
            assert_eq!(m.get(64, 1), 641.0);
            let cm = m.to_colmajor();
            assert_eq!(cm[0 * 150 + 5], 50.0);
            assert_eq!(cm[2 * 150 + 149], 1492.0);
        }
    }

    #[test]
    fn em_cache_evicts_and_flushes() {
        let ctx = DenseCtx::em_for_tests(32);
        // cache_slots = 1: creating b evicts a, flushing its data.
        let a = TasMatrix::from_fn(&ctx, 100, 2, |r, c| (r + c) as f64);
        assert!(a.is_resident());
        let written_before = ctx.fs.stats().bytes_written;
        let b = TasMatrix::zeros(&ctx, 100, 2);
        assert!(!a.is_resident(), "a should be evicted by b");
        assert!(b.is_resident());
        let written_after = ctx.fs.stats().bytes_written;
        assert_eq!(written_after - written_before, 100 * 2 * 8, "flush wrote a's data");
        // Data still correct after eviction (read from SSD now).
        assert_eq!(a.get(99, 1), 100.0);
    }

    #[test]
    fn cache_disabled_writes_through() {
        let fs = Safs::new(SafsConfig::untimed());
        let ctx = DenseCtx::with(fs, true, 32, 1, 2, 0, Arc::new(NativeKernels));
        let m = TasMatrix::from_fn(&ctx, 50, 2, |r, _| r as f64);
        assert!(!m.is_resident());
        assert_eq!(m.get(33, 0), 33.0);
        // All writes hit the array (zero-init + from_fn stores).
        assert!(ctx.fs.stats().bytes_written >= 2 * 50 * 2 * 8);
    }

    #[test]
    fn mem_mode_never_touches_ssd() {
        let ctx = DenseCtx::mem_for_tests(32);
        let m = TasMatrix::from_fn(&ctx, 100, 4, |r, c| (r * c) as f64);
        let _ = m.to_colmajor();
        assert_eq!(ctx.fs.stats().bytes_read, 0);
        assert_eq!(ctx.fs.stats().bytes_written, 0);
    }

    #[test]
    fn drop_deletes_file_and_frees_memory() {
        let ctx = DenseCtx::em_for_tests(32);
        let name;
        {
            let m = TasMatrix::zeros(&ctx, 64, 2);
            name = format!("tas-{}", m.id());
            assert!(ctx.fs.exists(&name));
            assert!(ctx.mem.current() > 0);
        }
        assert!(!ctx.fs.exists(&name));
        assert_eq!(ctx.mem.current(), 0);
    }

    #[test]
    fn interval_set_handles_aliasing() {
        let ctx = DenseCtx::mem_for_tests(64);
        let a = TasMatrix::from_fn(&ctx, 100, 2, |r, _| r as f64);
        let b = TasMatrix::from_fn(&ctx, 100, 2, |r, _| -(r as f64));
        let mut pool = BufferPool::new(true);
        // a appears twice — must not deadlock.
        let set = IntervalSet::load(&[&a, &b, &a], 0, &mut pool);
        assert_eq!(set.get(0)[1], 1.0);
        assert_eq!(set.get(1)[1], -1.0);
        assert_eq!(set.get(2)[1], 1.0);
        set.recycle(&mut pool);
    }

    #[test]
    fn mv_random_is_deterministic_and_backing_independent() {
        let c1 = DenseCtx::mem_for_tests(32);
        let c2 = DenseCtx::em_for_tests(32);
        let a = TasMatrix::zeros(&c1, 100, 3);
        let b = TasMatrix::zeros(&c2, 100, 3);
        mv_random(&a, 99);
        mv_random(&b, 99);
        assert_eq!(a.to_colmajor(), b.to_colmajor());
        let vals = a.to_colmajor();
        assert!(vals.iter().any(|&x| x != 0.0));
    }

    fn f32_ctx(em: bool, interval_rows: usize, cache_slots: usize) -> Arc<DenseCtx> {
        let mut cfg = SafsConfig::untimed();
        cfg.storage_precision = crate::safs::StoragePrecision::F32;
        let fs = Safs::new(cfg);
        DenseCtx::with(fs, em, interval_rows, 1, 2, cache_slots, Arc::new(NativeKernels))
    }

    #[test]
    fn f32_storage_halves_interval_bytes() {
        // Write-through EM (no cache): both the zero materialization and
        // the stores serialize at 4 bytes/element; reads load 4.
        let ctx = f32_ctx(true, 32, 0);
        let m = TasMatrix::from_fn(&ctx, 64, 2, |r, _| r as f64);
        assert_eq!(m.elem_bytes(), 4);
        let written = ctx.fs.stats().bytes_written;
        assert_eq!(written, 2 * 64 * 2 * 4, "zero-init + stores at f32 width");
        let before = ctx.fs.stats().bytes_read;
        let _ = m.to_colmajor();
        assert_eq!(ctx.fs.stats().bytes_read - before, 64 * 2 * 4);
    }

    #[test]
    fn f32_storage_rounds_at_store_and_roundtrips() {
        // 0.1 is not representable in f32: resident and evicted reads
        // must agree on the *rounded* value (the store boundary rounds
        // even while resident).
        for em in [false, true] {
            let ctx = f32_ctx(em, 32, 1);
            let m = TasMatrix::from_fn(&ctx, 40, 1, |r, _| 0.1 + r as f64);
            let expect = |r: usize| (0.1 + r as f64) as f32 as f64;
            assert_eq!(m.get(3, 0), expect(3));
            if em {
                m.flush();
                assert!(!m.is_resident());
                assert_eq!(m.get(3, 0), expect(3), "post-eviction bits unchanged");
                assert_eq!(m.get(35, 0), expect(35));
            }
        }
    }

    #[test]
    fn f32_update_interval_rounds_resident_fast_path() {
        let ctx = f32_ctx(false, 32, 1);
        let m = TasMatrix::zeros(&ctx, 10, 1);
        let mut pool = BufferPool::new(false);
        m.update_interval(0, &mut pool, |d| d[0] = 0.1);
        assert_eq!(m.get(0, 0), 0.1f32 as f64);
    }

    #[test]
    fn full_precision_scope_overrides_f32_storage() {
        let ctx = f32_ctx(true, 32, 0);
        let m = ctx.scoped_full_precision(|| TasMatrix::from_fn(&ctx, 32, 1, |r, _| 0.1 + r as f64));
        assert_eq!(m.elem_bytes(), 8);
        assert_eq!(m.get(5, 0), 0.1 + 5.0, "no f32 floor inside the scope");
        // Outside the scope the configured width applies again.
        assert_eq!(TasMatrix::zeros(&ctx, 32, 1).elem_bytes(), 4);
    }

    #[test]
    fn widen_stored_bytes_is_identity_at_f64() {
        let mut pool = BufferPool::new(false);
        let src = f64s_as_bytes(&[1.5, -2.25]).to_vec();
        let ptr = src.as_ptr();
        let out = widen_stored_bytes(src, 8, &mut pool);
        assert_eq!(out.as_ptr(), ptr, "no copy at full width");
        let narrow: Vec<u8> =
            [0.1f32, -7.5].iter().flat_map(|x| x.to_le_bytes()).collect();
        let wide = widen_stored_bytes(narrow, 4, &mut pool);
        assert_eq!(cast_f64s(&wide), &[0.1f32 as f64, -7.5]);
    }

    #[test]
    fn update_interval_read_modify_write() {
        for em in [false, true] {
            let ctx = if em {
                DenseCtx::em_for_tests(32)
            } else {
                DenseCtx::mem_for_tests(32)
            };
            let m = TasMatrix::from_fn(&ctx, 70, 2, |r, _| r as f64);
            let mut pool = BufferPool::new(true);
            m.update_interval(1, &mut pool, |d| d.iter_mut().for_each(|x| *x += 0.5));
            assert_eq!(m.get(40, 0), 40.5);
            assert_eq!(m.get(10, 0), 10.0);
        }
    }
}
