//! Named dataset configurations reproducing Table 2 at a scale factor.
//!
//! The paper's graphs (42M–3.4B vertices) do not fit this testbed; each
//! named dataset preserves the property the evaluation depends on —
//! degree distribution, directedness, weights, locality — while `scale`
//! shrinks vertex/edge counts proportionally (scale = 1/1024 by default
//! for benches; tests use smaller).

use super::{knn::knn, rmat::{rmat, RmatParams}, webgraph::{webgraph, WebGraphParams}};
use crate::sparse::CooMatrix;
use crate::util::rng::Rng;

/// The four graphs of Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    /// Twitter: 42M vertices, 1.5B edges, directed, power-law.
    Twitter,
    /// Friendster: 65M vertices, 1.7B edges (3.4B symmetric entries),
    /// undirected, power-law.
    Friendster,
    /// KNN distance graph: 62M vertices, 12B edges, undirected, weighted,
    /// regular degrees (100–1000).
    Knn,
    /// Page (Web Data Commons): 3.4B vertices, 129B edges, directed,
    /// domain-clustered.
    Page,
}

impl Dataset {
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::Twitter => "twitter",
            Dataset::Friendster => "friendster",
            Dataset::Knn => "knn",
            Dataset::Page => "page",
        }
    }

    pub fn from_name(name: &str) -> Option<Dataset> {
        match name {
            "twitter" => Some(Dataset::Twitter),
            "friendster" => Some(Dataset::Friendster),
            "knn" => Some(Dataset::Knn),
            "page" => Some(Dataset::Page),
            _ => None,
        }
    }

    pub fn all() -> [Dataset; 4] {
        [Dataset::Twitter, Dataset::Friendster, Dataset::Knn, Dataset::Page]
    }

    /// Paper-scale (vertices, edges) from Table 2.
    pub fn paper_scale(&self) -> (u64, u64) {
        match self {
            Dataset::Twitter => (42_000_000, 1_500_000_000),
            Dataset::Friendster => (65_000_000, 1_700_000_000),
            Dataset::Knn => (62_000_000, 12_000_000_000),
            Dataset::Page => (3_400_000_000, 129_000_000_000),
        }
    }

    pub fn directed(&self) -> bool {
        matches!(self, Dataset::Twitter | Dataset::Page)
    }

    pub fn weighted(&self) -> bool {
        matches!(self, Dataset::Knn)
    }

    /// Generate the dataset at `scale` (fraction of paper size).
    pub fn generate(&self, scale: f64, seed: u64) -> CooMatrix {
        let mut rng = Rng::new(seed ^ 0xDA7A_5E7 ^ (*self as u64) << 32);
        let (pn, pe) = self.paper_scale();
        let n = ((pn as f64 * scale) as u64).max(64);
        let m = ((pe as f64 * scale) as u64).max(256);
        match self {
            Dataset::Twitter => rmat(n, m, RmatParams::default(), &mut rng),
            Dataset::Friendster => {
                // Undirected: generate half the edges then symmetrise.
                let mut g = rmat(n, m / 2, RmatParams { a: 0.55, b: 0.2, c: 0.2 }, &mut rng);
                g.symmetrize();
                g
            }
            Dataset::Knn => {
                // Paper: ~100-NN symmetrised → degree 100–1000.  Scaled:
                // keep the edge:vertex ratio.
                let k = ((m / n.max(1)) as usize / 2).clamp(4, 128);
                knn(n, k, (8 * k) as u64, &mut rng)
            }
            Dataset::Page => {
                let mean_out = (pe as f64 / pn as f64).max(4.0);
                let params = WebGraphParams {
                    mean_domain: ((4096.0 * scale.sqrt()) as u64).clamp(32, 8192),
                    intra_prob: 0.8,
                    mean_out_degree: mean_out,
                };
                webgraph(n, params, &mut rng)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for d in Dataset::all() {
            assert_eq!(Dataset::from_name(d.name()), Some(d));
        }
        assert_eq!(Dataset::from_name("nope"), None);
    }

    #[test]
    fn generate_tiny_all() {
        for d in Dataset::all() {
            let g = d.generate(2e-5, 42);
            assert!(g.nnz() > 0, "{}", d.name());
            assert!(g.n_rows >= 64);
            if !d.directed() {
                assert!(g.is_symmetric(), "{} should be symmetric", d.name());
            }
            assert_eq!(g.values.is_some(), d.weighted(), "{}", d.name());
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Dataset::Twitter.generate(1e-5, 1);
        let b = Dataset::Twitter.generate(1e-5, 1);
        let c = Dataset::Twitter.generate(1e-5, 2);
        assert_eq!(a.entries, b.entries);
        assert_ne!(a.entries, c.entries);
    }
}
