//! Figure 12: KrylovSchur eigensolver — Trilinos-like and FE-SEM
//! relative to FE-IM across graphs and eigenvalue counts.
use flasheigen::graph::Dataset;
use flasheigen::harness::{fig12, BenchCfg};

fn main() {
    let mut cfg = BenchCfg::from_env();
    // Larger graphs so the EM subspace streams at bandwidth (not
    // latency); see EXPERIMENTS.md §Calibration.
    cfg.scale *= 2.0;
    fig12(
        &cfg,
        &[8, 16],
        &[Dataset::Twitter, Dataset::Friendster, Dataset::Knn],
    )
    .print();
}
