//! Worker-pool / parallel-for substrate with work stealing.
//!
//! `rayon` is not available offline; the paper's execution model is also
//! more specific than rayon's: each worker thread *owns* a contiguous range
//! of partitions (tile rows of the sparse matrix, row intervals of a dense
//! matrix) and steals from other workers only once its own range is
//! exhausted (§3.3.3 "load balancing").  [`OwnedQueues`] implements exactly
//! that; [`parallel_for`] is the convenience wrapper used by every matrix
//! operation in the repository.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Per-thread owned ranges with stealing.
///
/// Items `0..n` are split into `t` contiguous chunks, one per worker.  Each
/// worker pops from the front of its own chunk; when empty it scans other
/// workers round-robin and steals from the *back* of the victim's chunk to
/// minimise contention with the owner.
///
/// Head and tail are packed into ONE atomic per range and claimed with a
/// single CAS: with separate atomics, the owner (CAS on head) and a thief
/// (CAS on tail) can both claim the final remaining item — a real race
/// this repository's property tests caught in the wild.
pub struct OwnedQueues {
    /// `(head << 32) | tail` per worker; the worker owns `head..tail`.
    ranges: Vec<AtomicU64>,
    n_items: usize,
}

#[inline]
fn pack(head: usize, tail: usize) -> u64 {
    ((head as u64) << 32) | tail as u64
}

#[inline]
fn unpack(v: u64) -> (usize, usize) {
    ((v >> 32) as usize, (v & 0xFFFF_FFFF) as usize)
}

impl OwnedQueues {
    pub fn new(n_items: usize, n_workers: usize) -> OwnedQueues {
        assert!(n_workers > 0);
        assert!(n_items < u32::MAX as usize, "item count exceeds packing width");
        let per = n_items / n_workers;
        let extra = n_items % n_workers;
        let mut ranges = Vec::with_capacity(n_workers);
        let mut start = 0usize;
        for w in 0..n_workers {
            let len = per + usize::from(w < extra);
            ranges.push(AtomicU64::new(pack(start, start + len)));
            start += len;
        }
        debug_assert_eq!(start, n_items);
        OwnedQueues { ranges, n_items }
    }

    /// Pop the next item for `worker`, stealing if its own range is empty.
    /// Returns `None` when no work remains anywhere.
    pub fn pop(&self, worker: usize) -> Option<usize> {
        if let Some(i) = self.pop_own(worker) {
            return Some(i);
        }
        let t = self.ranges.len();
        for d in 1..t {
            let victim = (worker + d) % t;
            if let Some(i) = self.steal_from(victim) {
                return Some(i);
            }
        }
        None
    }

    /// Pop from the front of the worker's own range (CAS loop).
    pub fn pop_own(&self, worker: usize) -> Option<usize> {
        let range = &self.ranges[worker];
        loop {
            let v = range.load(Ordering::Acquire);
            let (h, t) = unpack(v);
            if h >= t {
                return None;
            }
            if range
                .compare_exchange_weak(v, pack(h + 1, t), Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return Some(h);
            }
        }
    }

    /// Steal from the back of a victim's range.
    fn steal_from(&self, victim: usize) -> Option<usize> {
        let range = &self.ranges[victim];
        loop {
            let v = range.load(Ordering::Acquire);
            let (h, t) = unpack(v);
            if h >= t {
                return None;
            }
            if range
                .compare_exchange_weak(v, pack(h, t - 1), Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return Some(t - 1);
            }
        }
    }
}

/// Statistics from one parallel run, used by the load-balancing ablations.
#[derive(Debug, Default, Clone)]
pub struct ParallelStats {
    /// Items processed per worker.
    pub per_worker: Vec<usize>,
    /// Of those, items stolen from another worker's range.
    pub stolen: usize,
}

/// Run `f(item, worker)` over items `0..n_items` on `n_workers` threads
/// with owned-range + stealing scheduling.  Panics in workers propagate.
pub fn parallel_for<F>(n_items: usize, n_workers: usize, f: F) -> ParallelStats
where
    F: Fn(usize, usize) + Sync,
{
    parallel_for_opt(n_items, n_workers, true, f)
}

/// Like [`parallel_for`], but stealing can be disabled to reproduce the
/// paper's static-partitioning baseline (Fig. 6 load-balancing ablation).
pub fn parallel_for_opt<F>(n_items: usize, n_workers: usize, steal: bool, f: F) -> ParallelStats
where
    F: Fn(usize, usize) + Sync,
{
    if n_items == 0 {
        return ParallelStats { per_worker: vec![0; n_workers], ..Default::default() };
    }
    if n_workers == 1 {
        for i in 0..n_items {
            f(i, 0);
        }
        return ParallelStats { per_worker: vec![n_items], stolen: 0 };
    }
    let queues = OwnedQueues::new(n_items, n_workers);
    let stolen = AtomicUsize::new(0);
    let counts: Vec<AtomicUsize> = (0..n_workers).map(|_| AtomicUsize::new(0)).collect();
    std::thread::scope(|s| {
        for w in 0..n_workers {
            let queues = &queues;
            let f = &f;
            let stolen = &stolen;
            let counts = &counts;
            s.spawn(move || {
                let owned = own_range(queues.n_items, counts.len(), w);
                loop {
                    let item = if steal {
                        queues.pop(w)
                    } else {
                        queues.pop_own(w)
                    };
                    let Some(i) = item else { break };
                    // Track steals: an item is stolen if it fell outside
                    // the worker's original static range.
                    if !(owned.0 <= i && i < owned.1) {
                        stolen.fetch_add(1, Ordering::Relaxed);
                    }
                    counts[w].fetch_add(1, Ordering::Relaxed);
                    f(i, w);
                }
            });
        }
    });
    ParallelStats {
        per_worker: counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
        stolen: stolen.load(Ordering::Relaxed),
    }
}

/// The static range worker `w` originally owned for `n` items, `t` workers.
fn own_range(n: usize, t: usize, w: usize) -> (usize, usize) {
    let per = n / t;
    let extra = n % t;
    let start = w * per + w.min(extra);
    let len = per + usize::from(w < extra);
    (start, start + len)
}

/// Split `0..n` into `chunks` contiguous (start, end) ranges.
pub fn split_ranges(n: usize, chunks: usize) -> Vec<(usize, usize)> {
    (0..chunks).map(|w| own_range(n, chunks, w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn all_items_processed_exactly_once() {
        for &(n, t) in &[(0usize, 3usize), (1, 4), (17, 4), (1000, 7), (64, 1)] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            parallel_for(n, t, |i, _| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "item {i} for n={n},t={t}");
            }
        }
    }

    #[test]
    fn stealing_balances_skewed_work() {
        // First quarter of items are 100x heavier; with stealing the
        // remaining workers should pick up the slack (all items done).
        let n = 64;
        let done = AtomicUsize::new(0);
        let stats = parallel_for(n, 4, |i, _| {
            let spins = if i < n / 4 { 20_000 } else { 200 };
            let mut x = i as u64;
            for _ in 0..spins {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            }
            std::hint::black_box(x);
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), n);
        assert_eq!(stats.per_worker.iter().sum::<usize>(), n);
    }

    #[test]
    fn no_steal_mode_processes_everything() {
        let n = 100;
        let done = AtomicUsize::new(0);
        let stats = parallel_for_opt(n, 3, false, |_, _| {
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), n);
        assert_eq!(stats.stolen, 0);
    }

    #[test]
    fn last_item_claimed_exactly_once_under_contention() {
        // Regression for the owner/thief double-claim race on the final
        // item of a range: hammer tiny queues from many threads.
        for round in 0..200 {
            let n = 1 + round % 3;
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            let q = OwnedQueues::new(n, 4);
            std::thread::scope(|s| {
                for w in 0..4 {
                    let q = &q;
                    let hits = &hits;
                    s.spawn(move || {
                        while let Some(i) = q.pop(w) {
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        }
                    });
                }
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "item {i} round {round}");
            }
        }
    }

    #[test]
    fn split_ranges_cover() {
        let rs = split_ranges(10, 3);
        assert_eq!(rs, vec![(0, 4), (4, 7), (7, 10)]);
        let rs = split_ranges(2, 5);
        assert_eq!(rs.iter().map(|(a, b)| b - a).sum::<usize>(), 2);
    }
}
