"""L1 Pallas kernel: the Gram block of MvTransMv (op3).

Computes ``GT + alpha * YT @ XT^T`` with XT:(m, rows), YT:(b, rows),
GT:(b, m) — the transposed convention of ref.py.

TPU mapping: the grid walks the `rows` axis; each step loads one
(m, RB) block of XT and one (b, RB) block of YT into VMEM and
accumulates into the (b, m) output block, which is *revisited* at every
step (constant index_map) — the Pallas analogue of the paper's
per-thread partial Gram matrices that are reduced at the end (§3.4.2).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_BLOCK = 4096


def _kernel(alpha_ref, xt_ref, yt_ref, gt_ref, o_ref):
    """Accumulating grid step: o += alpha * yt @ xt^T (init from gt)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = gt_ref[...]

    o_ref[...] += alpha_ref[0] * jnp.dot(
        yt_ref[...], xt_ref[...].T, preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("row_block",))
def gram(xt, yt, gt, alpha, *, row_block=DEFAULT_ROW_BLOCK):
    """Pallas Gram block: ``GT + alpha * YT @ XT^T``."""
    m, rows = xt.shape
    b, rows2 = yt.shape
    assert rows == rows2, (xt.shape, yt.shape)
    assert gt.shape == (b, m), (gt.shape, (b, m))
    if rows % row_block != 0:
        row_block = rows
    grid = (rows // row_block,)
    alpha_arr = jnp.asarray(alpha, dtype=gt.dtype).reshape((1,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((m, row_block), lambda i: (0, i)),
            pl.BlockSpec((b, row_block), lambda i: (0, i)),
            pl.BlockSpec((b, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), gt.dtype),
        interpret=True,
    )(alpha_arr, xt, yt, gt)
