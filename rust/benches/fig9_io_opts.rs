//! Figure 9: I/O optimization ablation on external-memory dense matrix
//! multiplication (MvTransMv form), plus the §3.4 lazy-evaluation
//! fusion ablation on CGS2 reorthogonalization (Figure 9b), the
//! streamed SpMM operator boundary ablation (Figure 9c), the streamed
//! two-hop Gram ablation for the SVD path (Figure 9d), the read-ahead
//! ablation on the streamed SEM apply (Figure 9e) and the cross-apply
//! image-residency ablation (Figure 9f).
use flasheigen::harness::{
    fig9, fig9_fusion, fig9_gram, fig9_imgcache, fig9_readahead, fig9_stream, BenchCfg,
};

fn main() {
    let cfg = BenchCfg::from_env();
    // Paper: n=60M scaled; m=64 vectors of width 4.
    let n = (60_000_000.0 * cfg.scale * 16.0) as usize;
    fig9(&cfg, n.max(4096), 64, 4).print();
    fig9_fusion(&cfg, n.max(4096), 64, 4).print();
    // 16x the base scale so the subspace spans several row intervals —
    // streaming is the identity transformation on a single interval.
    fig9_stream(&cfg, 16.0, 4).print();
    fig9_gram(&cfg, 1.0, 4).print();
    fig9_readahead(&cfg, 16.0, 4).print();
    fig9_imgcache(&cfg, 16.0, 4).print();
}
