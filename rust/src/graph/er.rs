//! Erdős–Rényi G(n, m) generator — used by tests and as an unstructured
//! control workload.

use crate::sparse::CooMatrix;
use crate::util::rng::Rng;

/// Directed G(n, m): `m` distinct uniformly random edges, no self loops.
pub fn gnm(n: u64, m: u64, rng: &mut Rng) -> CooMatrix {
    assert!(m <= n * (n - 1));
    let mut coo = CooMatrix::new(n, n);
    coo.entries.reserve(m as usize);
    while (coo.entries.len() as u64) < m {
        let need = m as usize - coo.entries.len();
        for _ in 0..need + need / 8 + 1 {
            let r = rng.gen_range(n) as u32;
            let c = rng.gen_range(n) as u32;
            if r != c {
                coo.push(r, c);
            }
        }
        coo.sort_dedup();
    }
    coo.entries.truncate(m as usize);
    coo
}

/// Undirected (symmetric) G(n, m).
pub fn gnm_undirected(n: u64, m: u64, rng: &mut Rng) -> CooMatrix {
    let mut coo = gnm(n, m, rng);
    coo.symmetrize();
    coo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_edge_count() {
        let mut rng = Rng::new(10);
        let g = gnm(100, 500, &mut rng);
        assert_eq!(g.nnz(), 500);
        assert!(g.entries.iter().all(|&(r, c)| r != c));
    }

    #[test]
    fn undirected_is_symmetric() {
        let mut rng = Rng::new(11);
        let g = gnm_undirected(50, 100, &mut rng);
        assert!(g.is_symmetric());
    }
}
